//! The concrete model catalog.
//!
//! FC shapes and multiplicities for every model follow the paper's
//! Tables 1-2 exactly. Conv stacks are standard-architecture encodings
//! (LeNet/AlexNet/VGG16 exact; ResNet/GoogleNet/Xception as aggregate conv
//! budgets at published totals) — they only feed the FC-share figures
//! (Figs. 1 and 11), not the DSE tables.

use super::{Family, LayerSpec, ModelArch};
use LayerSpec::{AttnMatmul, Conv, Embed, Fc, Norm};

fn cnn(name: &'static str, dataset: &'static str, layers: Vec<(LayerSpec, u64)>) -> ModelArch {
    ModelArch { name, family: Family::Cnn, dataset, layers }
}

/// GPT-family block: per transformer layer 4x [dim, dim] projections,
/// [dim, 4*dim] + [4*dim, dim] feed-forward, 2 norms, attention matmuls;
/// plus embedding and the LM head [dim, vocab] (paper Table 2 rows).
fn gpt(
    name: &'static str,
    layers_n: u64,
    dim: u64,
    seq: u64,
    vocab: u64,
) -> ModelArch {
    let layers = vec![
        (Embed { vocab, dim }, 1),
        (Embed { vocab: seq, dim }, 1), // positional table
        (Fc { n: dim, m: dim, tokens: seq }, 4 * layers_n),
        (Fc { n: dim, m: 4 * dim, tokens: seq }, layers_n),
        (Fc { n: 4 * dim, m: dim, tokens: seq }, layers_n),
        (Norm { dim, tokens: seq }, 2 * layers_n + 1),
        (AttnMatmul { seq, dim }, layers_n),
        (Fc { n: dim, m: vocab, tokens: 1 }, 1), // LM head (last position)
    ];
    ModelArch { name, family: Family::Llm, dataset: "WebText", layers }
}

/// Every model in the paper's evaluation, CNNs first.
pub fn all_models() -> Vec<ModelArch> {
    let mut v = cnn_models();
    v.extend(llm_models());
    v
}

/// The paper's CNN suite (Table 1).
pub fn cnn_models() -> Vec<ModelArch> {
    vec![
        cnn("LeNet5", "MNIST", vec![
            (Conv { c_in: 1, c_out: 6, k: 5, out_h: 28, out_w: 28 }, 1),
            (Conv { c_in: 6, c_out: 16, k: 5, out_h: 10, out_w: 10 }, 1),
            (Fc { n: 400, m: 120, tokens: 1 }, 1),
            (Fc { n: 120, m: 84, tokens: 1 }, 1),
            (Fc { n: 84, m: 10, tokens: 1 }, 1),
        ]),
        cnn("LeNet300", "MNIST", vec![
            (Fc { n: 784, m: 300, tokens: 1 }, 1),
            (Fc { n: 300, m: 100, tokens: 1 }, 1),
            (Fc { n: 100, m: 10, tokens: 1 }, 1),
        ]),
        cnn("AlexNet-CIFAR10", "CIFAR10", vec![
            (Conv { c_in: 3, c_out: 64, k: 3, out_h: 32, out_w: 32 }, 1),
            (Conv { c_in: 64, c_out: 192, k: 3, out_h: 16, out_w: 16 }, 1),
            (Conv { c_in: 192, c_out: 384, k: 3, out_h: 8, out_w: 8 }, 1),
            (Conv { c_in: 384, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 1),
            (Conv { c_in: 256, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 1),
            (Fc { n: 4096, m: 2048, tokens: 1 }, 1),
            (Fc { n: 2048, m: 2048, tokens: 1 }, 1),
            (Fc { n: 2048, m: 10, tokens: 1 }, 1),
        ]),
        cnn("AlexNet-CIFAR100", "CIFAR100", vec![
            (Conv { c_in: 3, c_out: 64, k: 3, out_h: 32, out_w: 32 }, 1),
            (Conv { c_in: 64, c_out: 192, k: 3, out_h: 16, out_w: 16 }, 1),
            (Conv { c_in: 192, c_out: 384, k: 3, out_h: 8, out_w: 8 }, 1),
            (Conv { c_in: 384, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 1),
            (Conv { c_in: 256, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 1),
            (Fc { n: 4096, m: 2048, tokens: 1 }, 1),
            (Fc { n: 2048, m: 2048, tokens: 1 }, 1),
            (Fc { n: 2048, m: 100, tokens: 1 }, 1),
        ]),
        cnn("AlexNet-ImageNet", "ImageNet", vec![
            (Conv { c_in: 3, c_out: 96, k: 11, out_h: 55, out_w: 55 }, 1),
            (Conv { c_in: 96, c_out: 256, k: 5, out_h: 27, out_w: 27 }, 1),
            (Conv { c_in: 256, c_out: 384, k: 3, out_h: 13, out_w: 13 }, 1),
            (Conv { c_in: 384, c_out: 384, k: 3, out_h: 13, out_w: 13 }, 1),
            (Conv { c_in: 384, c_out: 256, k: 3, out_h: 13, out_w: 13 }, 1),
            (Fc { n: 9216, m: 4096, tokens: 1 }, 1),
            (Fc { n: 4096, m: 4096, tokens: 1 }, 1),
            (Fc { n: 4096, m: 1000, tokens: 1 }, 1),
        ]),
        cnn("VGG-CIFAR10", "CIFAR10", vec![
            (Conv { c_in: 3, c_out: 64, k: 3, out_h: 32, out_w: 32 }, 2),
            (Conv { c_in: 64, c_out: 128, k: 3, out_h: 16, out_w: 16 }, 2),
            (Conv { c_in: 128, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 3),
            (Conv { c_in: 256, c_out: 512, k: 3, out_h: 4, out_w: 4 }, 3),
            (Conv { c_in: 512, c_out: 512, k: 3, out_h: 2, out_w: 2 }, 3),
            (Fc { n: 512, m: 512, tokens: 1 }, 1),
            (Fc { n: 512, m: 256, tokens: 1 }, 1),
            (Fc { n: 256, m: 10, tokens: 1 }, 1),
        ]),
        cnn("VGG-CIFAR100", "CIFAR100", vec![
            (Conv { c_in: 3, c_out: 64, k: 3, out_h: 32, out_w: 32 }, 2),
            (Conv { c_in: 64, c_out: 128, k: 3, out_h: 16, out_w: 16 }, 2),
            (Conv { c_in: 128, c_out: 256, k: 3, out_h: 8, out_w: 8 }, 3),
            (Conv { c_in: 256, c_out: 512, k: 3, out_h: 4, out_w: 4 }, 3),
            (Conv { c_in: 512, c_out: 512, k: 3, out_h: 2, out_w: 2 }, 3),
            (Fc { n: 512, m: 512, tokens: 1 }, 1),
            (Fc { n: 512, m: 256, tokens: 1 }, 1),
            (Fc { n: 256, m: 100, tokens: 1 }, 1),
        ]),
        cnn("VGG16-ImageNet", "ImageNet", vec![
            (Conv { c_in: 3, c_out: 64, k: 3, out_h: 224, out_w: 224 }, 2),
            (Conv { c_in: 64, c_out: 128, k: 3, out_h: 112, out_w: 112 }, 2),
            (Conv { c_in: 128, c_out: 256, k: 3, out_h: 56, out_w: 56 }, 3),
            (Conv { c_in: 256, c_out: 512, k: 3, out_h: 28, out_w: 28 }, 3),
            (Conv { c_in: 512, c_out: 512, k: 3, out_h: 14, out_w: 14 }, 3),
            (Fc { n: 25088, m: 4096, tokens: 1 }, 1),
            (Fc { n: 4096, m: 4096, tokens: 1 }, 1),
            (Fc { n: 4096, m: 1000, tokens: 1 }, 1),
        ]),
        // Aggregate conv budgets at published totals (params ~23.5M/5.8M/20.8M,
        // FLOPs ~2x GMACs) — only the FC/non-FC split matters downstream.
        cnn("ResNet-ImageNet", "ImageNet", vec![
            (Conv { c_in: 512, c_out: 512, k: 3, out_h: 44, out_w: 44 }, 10),
            (Fc { n: 2048, m: 1000, tokens: 1 }, 1),
        ]),
        cnn("GoogleNet-ImageNet", "ImageNet", vec![
            (Conv { c_in: 256, c_out: 256, k: 3, out_h: 32, out_w: 32 }, 10),
            (Fc { n: 1024, m: 1000, tokens: 1 }, 1),
        ]),
        cnn("Xception-ImageNet", "ImageNet", vec![
            (Conv { c_in: 512, c_out: 512, k: 3, out_h: 41, out_w: 41 }, 9),
            (Fc { n: 2048, m: 1000, tokens: 1 }, 1),
        ]),
    ]
}

/// The paper's LLM suite (Table 2). Layer counts / dims follow the table's
/// FC multiplicities (e.g. "24*4*([1024, 1024])" = 24 blocks, 4 projections).
pub fn llm_models() -> Vec<ModelArch> {
    vec![
        gpt("GPT2-Medium", 24, 1024, 1024, 50257),
        gpt("GPT2-Large", 36, 1280, 1024, 50257),
        gpt("GPT2-ExtraLarge", 48, 1600, 1024, 50257),
        gpt("GPT3-Ada", 12, 768, 2048, 50257),
        gpt("GPT3-Curie", 24, 2048, 2048, 50257),
        gpt("GPT3-Davinci", 96, 12288, 2048, 50257),
    ]
}

/// Look a model up by (case-insensitive) name.
pub fn model_by_name(name: &str) -> Option<ModelArch> {
    all_models()
        .into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_complete() {
        assert_eq!(cnn_models().len(), 11);
        assert_eq!(llm_models().len(), 6);
        assert!(model_by_name("lenet300").is_some());
        assert!(model_by_name("gpt3-davinci").is_some());
        assert!(model_by_name("nope").is_none());
    }

    #[test]
    fn table1_fc_shapes_present() {
        // spot-check Table 1 rows
        let lenet5 = model_by_name("LeNet5").unwrap();
        let shapes = lenet5.fc_shapes();
        assert!(shapes.iter().any(|s| s.n == 400 && s.m == 120));
        assert!(shapes.iter().any(|s| s.n == 120 && s.m == 84));

        let alex = model_by_name("AlexNet-ImageNet").unwrap();
        let shapes = alex.fc_shapes();
        assert!(shapes.iter().any(|s| s.n == 9216 && s.m == 4096));

        let vgg = model_by_name("VGG16-ImageNet").unwrap();
        assert!(vgg.fc_shapes().iter().any(|s| s.n == 25088 && s.m == 4096));
    }

    #[test]
    fn table2_fc_multiplicities() {
        let m = model_by_name("GPT2-Medium").unwrap();
        let shapes = m.fc_shapes();
        // 24*4 projections [1024,1024]
        assert!(shapes
            .iter()
            .any(|s| s.n == 1024 && s.m == 1024 && s.count == 96));
        // 24 of [1024, 4096] and [4096, 1024]
        assert!(shapes
            .iter()
            .any(|s| s.n == 1024 && s.m == 4096 && s.count == 24));
        assert!(shapes
            .iter()
            .any(|s| s.n == 4096 && s.m == 1024 && s.count == 24));
        // LM head [1024, 50257]
        assert!(shapes.iter().any(|s| s.n == 1024 && s.m == 50257));
    }

    #[test]
    fn lenet300_is_fc_dominated() {
        // paper Fig. 11: 97.6% of LeNet300 execution is FC; parameter share
        // must likewise be ~100%
        let m = model_by_name("LeNet300").unwrap();
        assert!(m.fc_param_share() > 99.0);
        assert!(m.fc_flops_share() > 99.0);
    }

    #[test]
    fn conv_models_have_low_fc_flops_share() {
        // paper Fig. 1: conv nets burn most FLOPs outside FC
        for name in ["VGG16-ImageNet", "ResNet-ImageNet", "Xception-ImageNet"] {
            let m = model_by_name(name).unwrap();
            assert!(
                m.fc_flops_share() < 15.0,
                "{name} fc flops share {}",
                m.fc_flops_share()
            );
        }
        // ...while FC dominates VGG16 parameters
        let vgg = model_by_name("VGG16-ImageNet").unwrap();
        assert!(vgg.fc_param_share() > 70.0, "{}", vgg.fc_param_share());
    }

    #[test]
    fn llms_are_fc_dominated_in_params() {
        for m in llm_models() {
            assert!(
                m.fc_param_share() > 55.0,
                "{} share {}",
                m.name,
                m.fc_param_share()
            );
        }
        // bigger models: larger share (embeddings amortize)
        let ada = model_by_name("GPT3-Ada").unwrap();
        let davinci = model_by_name("GPT3-Davinci").unwrap();
        assert!(davinci.fc_param_share() > ada.fc_param_share());
    }

    #[test]
    fn published_total_sanity() {
        // GPT2-Medium ~ 350-400M params
        let m = model_by_name("GPT2-Medium").unwrap();
        let (fc, other) = m.params_split();
        let total = fc + other;
        assert!(
            (300_000_000..500_000_000).contains(&total),
            "GPT2-Medium total {total}"
        );
        // VGG16 ~ 138M params
        let v = model_by_name("VGG16-ImageNet").unwrap();
        let (fc, other) = v.params_split();
        assert!(
            (120_000_000..160_000_000).contains(&(fc + other)),
            "VGG16 total {}",
            fc + other
        );
    }
}
