//! Architecture catalog: the 7 CNNs and 6 LLMs of the paper's evaluation
//! (Tables 1-2, Figs. 1 and 11), as parameter/FLOP layer inventories.
//!
//! Weights are not stored — Tables 1-2 and the share figures depend only on
//! architecture shapes. Conv stacks are encoded at standard-architecture
//! fidelity (documented per model); LLM blocks follow the paper's own
//! estimates (Table 2 lists the exact FC shapes and multiplicities).

mod zoo;

pub use zoo::{all_models, cnn_models, llm_models, model_by_name};

/// One layer kind with enough detail to count parameters and FLOPs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerSpec {
    /// 2D convolution producing `out_h x out_w` spatial output.
    Conv { c_in: u64, c_out: u64, k: u64, out_h: u64, out_w: u64 },
    /// Fully connected `N -> M` applied at `tokens` positions per forward
    /// (1 for CNN heads; the sequence length for transformer sub-layers —
    /// parameters are shared, FLOPs scale with `tokens`).
    Fc { n: u64, m: u64, tokens: u64 },
    /// Token embedding lookup (parameters only, no MACs).
    Embed { vocab: u64, dim: u64 },
    /// LayerNorm / BatchNorm over `dim` features across `tokens` positions.
    Norm { dim: u64, tokens: u64 },
    /// Attention score+context matmuls (the non-FC part of self-attention):
    /// `2 * seq^2 * dim` MACs per head-group, `seq` tokens.
    AttnMatmul { seq: u64, dim: u64 },
}

impl LayerSpec {
    /// Trainable parameter count.
    pub fn params(&self) -> u64 {
        match *self {
            LayerSpec::Conv { c_in, c_out, k, .. } => c_in * c_out * k * k + c_out,
            LayerSpec::Fc { n, m, .. } => n * m + m,
            LayerSpec::Embed { vocab, dim } => vocab * dim,
            LayerSpec::Norm { dim, .. } => 2 * dim,
            LayerSpec::AttnMatmul { .. } => 0,
        }
    }

    /// Inference FLOPs (one forward pass; 2 per MAC).
    pub fn flops(&self) -> u64 {
        match *self {
            LayerSpec::Conv { c_in, c_out, k, out_h, out_w } => {
                2 * c_in * c_out * k * k * out_h * out_w
            }
            LayerSpec::Fc { n, m, tokens } => (2 * n * m + m) * tokens,
            LayerSpec::Embed { .. } => 0,
            LayerSpec::Norm { dim, tokens } => 5 * dim * tokens,
            LayerSpec::AttnMatmul { seq, dim } => 2 * 2 * seq * seq * dim,
        }
    }

    /// Whether this layer is an FC layer (the factorization target).
    pub fn is_fc(&self) -> bool {
        matches!(self, LayerSpec::Fc { .. })
    }
}

/// Model family tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Convolutional model (paper Table 1).
    Cnn,
    /// Transformer model (paper Table 2).
    Llm,
}

/// A model architecture: named layers with multiplicities.
#[derive(Debug, Clone)]
pub struct ModelArch {
    /// Model name as the paper's tables print it.
    pub name: &'static str,
    /// CNN vs LLM.
    pub family: Family,
    /// Dataset tag as the paper's tables print it.
    pub dataset: &'static str,
    /// (layer, multiplicity) pairs.
    pub layers: Vec<(LayerSpec, u64)>,
}

/// An FC layer occurrence eligible for factorization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FcShape {
    /// Input width `N`.
    pub n: u64,
    /// Output width `M`.
    pub m: u64,
    /// How many identical instances the model contains.
    pub count: u64,
}

impl ModelArch {
    /// FC layers of the model (paper Tables 1-2 rows), in definition order.
    pub fn fc_shapes(&self) -> Vec<FcShape> {
        self.layers
            .iter()
            .filter_map(|(l, count)| match *l {
                LayerSpec::Fc { n, m, .. } => Some(FcShape { n, m, count: *count }),
                _ => None,
            })
            .collect()
    }

    /// (fc, non_fc) parameter totals — Fig. 1 left.
    pub fn params_split(&self) -> (u64, u64) {
        self.split(LayerSpec::params)
    }

    /// (fc, non_fc) FLOP totals — Fig. 1 right.
    pub fn flops_split(&self) -> (u64, u64) {
        self.split(LayerSpec::flops)
    }

    fn split(&self, f: impl Fn(&LayerSpec) -> u64) -> (u64, u64) {
        let mut fc = 0;
        let mut other = 0;
        for (l, count) in &self.layers {
            let v = f(l) * count;
            if l.is_fc() {
                fc += v;
            } else {
                other += v;
            }
        }
        (fc, other)
    }

    /// FC share of parameters in percent.
    pub fn fc_param_share(&self) -> f64 {
        let (fc, other) = self.params_split();
        100.0 * fc as f64 / (fc + other).max(1) as f64
    }

    /// FC share of FLOPs in percent.
    pub fn fc_flops_share(&self) -> f64 {
        let (fc, other) = self.flops_split();
        100.0 * fc as f64 / (fc + other).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_cost_formulas() {
        let fc = LayerSpec::Fc { n: 784, m: 300, tokens: 1 };
        assert_eq!(fc.params(), 784 * 300 + 300);
        assert_eq!(fc.flops(), 2 * 784 * 300 + 300);
        let fc_seq = LayerSpec::Fc { n: 784, m: 300, tokens: 4 };
        assert_eq!(fc_seq.params(), fc.params());
        assert_eq!(fc_seq.flops(), 4 * fc.flops());
        let conv = LayerSpec::Conv { c_in: 3, c_out: 16, k: 3, out_h: 32, out_w: 32 };
        assert_eq!(conv.params(), 3 * 16 * 9 + 16);
        assert_eq!(conv.flops(), 2 * 3 * 16 * 9 * 32 * 32);
        assert_eq!(LayerSpec::Embed { vocab: 10, dim: 4 }.flops(), 0);
        assert!(!conv.is_fc());
        assert!(fc.is_fc());
    }

    #[test]
    fn split_respects_multiplicity() {
        let arch = ModelArch {
            name: "toy",
            family: Family::Llm,
            dataset: "none",
            layers: vec![
                (LayerSpec::Fc { n: 10, m: 10, tokens: 1 }, 3),
                (LayerSpec::Norm { dim: 10, tokens: 1 }, 2),
            ],
        };
        let (fc, other) = arch.params_split();
        assert_eq!(fc, 3 * 110);
        assert_eq!(other, 2 * 20);
        assert_eq!(arch.fc_shapes(), vec![FcShape { n: 10, m: 10, count: 3 }]);
    }
}
