//! The `.ttrv` bundle container format: magic, version, TOC, checksums and
//! the bounds-checked binary read/write primitives the [`super::writer`] /
//! [`super::reader`] pair is built on.
//!
//! # Byte layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic "TTRV"
//! 4       4     u32 format version (currently 4; reader accepts 1..=4)
//! 8       4     u32 section count (<= 64)
//! 12      4     u32 CRC-32 of the TOC bytes
//! 16      24*c  TOC entries: { u32 id, u32 payload CRC-32,
//!                              u64 payload offset, u64 payload length }
//! ...           section payloads (offsets are absolute file offsets)
//! ```
//!
//! # Versioning policy
//!
//! The version is a single monotonically increasing integer. The writer
//! always stamps [`FORMAT_VERSION`]; the reader accepts the inclusive
//! range [`MIN_FORMAT_VERSION`]`..=`[`FORMAT_VERSION`] (anything outside
//! it is rejected with a typed [`Error::Artifact`] naming the supported
//! range). **Additive** changes — a new optional section id (the TUNE
//! section of version 2) or a new optional trailing field in an existing
//! section's grammar (the TUNE kernel name of version 3) — bump
//! [`FORMAT_VERSION`] only, so every pre-bump bundle keeps loading and new
//! readers fall back to the old behavior when the section or field is
//! absent. **Breaking** changes (container
//! layout, an existing section's grammar or semantics) bump
//! [`MIN_FORMAT_VERSION`] up to the same value, cutting old files off
//! loudly. Unknown section ids within a supported version are skipped, so
//! third-party additive sections also survive. The pinned golden bundle in
//! `rust/tests/data/` (version 1, no TUNE section) is the tripwire: a
//! format change that forgets the policy fails its load test.
//!
//! # CRC scheme
//!
//! Standard CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`, init and
//! final XOR `0xFFFFFFFF` — the zlib/`crc32` algorithm). One checksum over
//! the TOC bytes (header field 3) and one per section payload (TOC field 2);
//! every checksum is verified before the payload is decoded.

use crate::error::{Error, Result};

/// File magic: the first four bytes of every bundle.
pub const MAGIC: [u8; 4] = *b"TTRV";

/// Current container format version (see the versioning policy above).
/// Version 2 added the optional TUNE section ([`SEC_TUNE`]); version 3
/// appended the optional tuning-kernel name to the TUNE payload (the
/// microkernel `tune_chain` measured its winners on — observability only,
/// never used for load-time dispatch); version 4 added the optional QUANT
/// section ([`SEC_QUANT`]) carrying int8-quantized TT cores.
pub const FORMAT_VERSION: u32 = 4;

/// Oldest format version the reader still accepts (version 1 bundles have
/// no TUNE section and decode with analytic plans only).
pub const MIN_FORMAT_VERSION: u32 = 1;

/// Upper bound on TOC entries — far above any real bundle, small enough
/// that a corrupted count cannot drive a large allocation.
pub const MAX_SECTIONS: u32 = 64;

/// Fixed header size in bytes (magic + version + section count + TOC CRC).
pub const HEADER_LEN: usize = 16;

/// Size of one TOC entry in bytes.
pub const TOC_ENTRY_LEN: usize = 24;

/// Section id: bundle metadata (JSON — model name, dims, machine, seed).
pub const SEC_META: u32 = 1;
/// Section id: the layer ops (binary — cores, plans, weights, biases).
pub const SEC_OPS: u32 = 2;
/// Section id: the embedded DSE report (JSON — per-layer stage counts,
/// frontier and selection).
pub const SEC_REPORT: u32 = 3;
/// Section id (format version >= 2, optional): measured-autotuned
/// [`crate::compiler::OptimizationPlan`]s per TT layer — the output of
/// `ttrv compress --tune` ([`crate::kernels::Executor::tune_chain`]).
/// Absent = serve with the analytic plans in the OPS section.
pub const SEC_TUNE: u32 = 4;
/// Section id (format version >= 4, optional): int8-quantized TT cores —
/// per-`m`-slice scales plus the int8 payload for every packed core of
/// every TT layer, the output of `ttrv compress --quantize`
/// ([`crate::kernels::quantize`]). Absent = serve the f32 packed cores in
/// the OPS section. Quantization is deterministic, so the section is
/// always cross-validated against the OPS cores on load.
pub const SEC_QUANT: u32 = 5;

// CRC-32 (IEEE) lookup table, built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE / zlib) of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Write primitives
// ---------------------------------------------------------------------------

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a little-endian `u32`.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64`.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `f64`.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a slice of `f32`s, each little-endian.
pub fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// ---------------------------------------------------------------------------
// Read primitives
// ---------------------------------------------------------------------------

/// A bounds-checked forward reader over a byte slice. Every accessor
/// returns a typed [`Error::Artifact`] instead of panicking when the input
/// is truncated, and every element-count helper validates the count against
/// the *remaining bytes* before any allocation happens — the decoder can be
/// fed arbitrary bytes without panic or OOM.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Human-readable section name for error messages.
    what: &'static str,
}

impl<'a> Cursor<'a> {
    /// A cursor over `buf`; `what` names the section in error messages.
    pub fn new(buf: &'a [u8], what: &'static str) -> Self {
        Cursor { buf, pos: 0, what }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Whether every byte has been consumed.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn err(&self, msg: &str) -> Error {
        Error::artifact(format!("{}: {msg} (at byte {})", self.what, self.pos))
    }

    /// Take `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(self.err(&format!(
                "truncated: need {n} bytes, {} remain",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a little-endian `f64`.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a little-endian `u64` and convert it to `usize`, requiring it
    /// to be at most `cap` (a semantic bound like "a tensor dimension" —
    /// callers pass the tightest bound they know).
    pub fn usize_capped(&mut self, cap: usize, what: &str) -> Result<usize> {
        let v = self.u64()?;
        if v > cap as u64 {
            return Err(self.err(&format!("{what} = {v} exceeds bound {cap}")));
        }
        Ok(v as usize)
    }

    /// Read an element count that precedes `count * elem_size` bytes of
    /// payload. Validated against the remaining bytes **before** any
    /// allocation, so a corrupted length field cannot OOM the reader.
    pub fn count(&mut self, elem_size: usize, what: &str) -> Result<usize> {
        debug_assert!(elem_size > 0);
        let v = self.u64()?;
        let max = (self.remaining() / elem_size) as u64;
        if v > max {
            return Err(self.err(&format!(
                "{what} = {v} elements x {elem_size} B exceeds the {} remaining bytes",
                self.remaining()
            )));
        }
        Ok(v as usize)
    }

    /// Read exactly `n` little-endian `f32`s (the caller has already
    /// validated `n` against the remaining bytes via [`Cursor::count`] or
    /// an expected-size formula).
    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| self.err("f32 count overflow"))?)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// A typed decode error at the current position (for semantic checks
    /// the caller performs on already-read values).
    pub fn invalid(&self, msg: impl AsRef<str>) -> Error {
        self.err(msg.as_ref())
    }
}

/// Checked `a * b` for section-size arithmetic, as a typed artifact error.
pub fn checked_mul(a: usize, b: usize, what: &str) -> Result<usize> {
    a.checked_mul(b)
        .ok_or_else(|| Error::artifact(format!("{what}: size {a} x {b} overflows")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_ieee_reference_vectors() {
        // the canonical CRC-32 check value and a couple of zlib-confirmed
        // vectors (cross-checked against python zlib.crc32)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"TTRV"), 0x041B_0A92);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn primitives_roundtrip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_f64(&mut buf, -1.5);
        put_f32s(&mut buf, &[1.0, -0.0, f32::MIN_POSITIVE]);
        let mut c = Cursor::new(&buf, "test");
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.u64().unwrap(), u64::MAX - 1);
        assert_eq!(c.f64().unwrap(), -1.5);
        let fs = c.f32s(3).unwrap();
        assert_eq!(fs[0].to_bits(), 1.0f32.to_bits());
        assert_eq!(fs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(fs[2].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert!(c.is_empty());
    }

    #[test]
    fn truncation_is_a_typed_error_not_a_panic() {
        let mut c = Cursor::new(&[1, 2, 3], "test");
        assert!(matches!(c.u32().unwrap_err(), Error::Artifact(_)));
        let mut c = Cursor::new(&[], "test");
        assert!(matches!(c.u8().unwrap_err(), Error::Artifact(_)));
    }

    #[test]
    fn oversized_count_fails_before_allocation() {
        // a length field claiming u64::MAX elements must be rejected by
        // comparing against the remaining bytes, never passed to Vec
        let mut buf = Vec::new();
        put_u64(&mut buf, u64::MAX);
        let mut c = Cursor::new(&buf, "test");
        let err = c.count(4, "floats").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        assert!(err.to_string().contains("floats"));
    }

    #[test]
    fn usize_capped_enforces_bound() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 100);
        let mut c = Cursor::new(&buf, "test");
        assert!(c.usize_capped(64, "d").is_err());
        let mut c = Cursor::new(&buf, "test");
        assert_eq!(c.usize_capped(128, "d").unwrap(), 100);
    }
}
