//! The in-memory form of a `.ttrv` bundle and the two pipelines around it:
//! **compress** (DSE route → TT-SVD → compile → pack → bundle) and
//! **warm-start** (bundle → engines with pre-seeded plan caches, zero DSE
//! and zero decomposition at load time).
//!
//! A bundle is plain data — layouts, packed core buffers, compiled plans,
//! dense weights, biases — never live engines, so it can be written,
//! diffed and round-tripped without touching executor state. Engines are
//! stamped out on demand by [`ModelBundle::build_engine`].

use crate::baselines::dense::DenseFc;
use crate::compiler::OptimizationPlan;
use crate::config::DseConfig;
use crate::coordinator::router::{self, Route};
use crate::coordinator::{LayerOp, ModelEngine, TtFcEngine};
use crate::dse::report::{timed_solution_json, MIN_FC_DIM};
use crate::dse::{self, TimedExplored, TimedSolution};
use crate::error::{Error, Result};
use crate::kernels::{pack, quantize, Executor, PackedG, QuantizedG};
use crate::machine::MachineSpec;
use crate::models;
use crate::tensor::Tensor;
use crate::ttd::cost::einsum_chain;
use crate::ttd::decompose::tt_svd;
use crate::ttd::TtLayout;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// Frontier entries embedded per layer in the bundle's DSE report; the
/// report records the full frontier size alongside so the cap is never a
/// silent truncation.
const REPORT_FRONTIER_CAP: usize = 32;

/// A TT-compressed FC layer as stored in a bundle: everything the serving
/// engine needs, already in execution form.
#[derive(Debug, Clone, PartialEq)]
pub struct TtLayerBundle {
    /// The layout the stored cores realize (achieved TT-SVD ranks, which
    /// the decomposition may have clipped below the selected solution's).
    pub layout: TtLayout,
    /// Packed core per chain step, processing order (t = d-1 .. 0), in the
    /// `G` layout each step's plan chose.
    pub packed: Vec<PackedG>,
    /// Compiled batch-1 plan per chain step (processing order) — pre-seeds
    /// the executor's plan cache at load.
    pub plans: Vec<OptimizationPlan>,
    /// Output bias (length `M`), if any.
    pub bias: Option<Vec<f32>>,
    /// The DSE-selected, time-qualified solution this layer deployed.
    pub selected: TimedSolution,
    /// Measured-autotuned batch-1 plans (same chain order/dims as `plans`,
    /// RB factors / thread counts re-ranked by measurement —
    /// [`crate::kernels::Executor::tune_chain`]). Persisted as the
    /// optional TUNE section; `None` = serve with the analytic `plans`.
    /// Tuned plans never change the packed `G` layout or any result bit.
    pub tuned: Option<Vec<OptimizationPlan>>,
    /// Int8-quantized shadow of `packed` (same chain order, same `G`
    /// layouts — [`crate::kernels::quantize`] per core). Persisted as the
    /// optional QUANT section (format v4); `None` = serve the f32 cores.
    /// Quantization is deterministic, so [`verify`] can re-derive and
    /// byte-compare this section like any other.
    pub quant: Option<Vec<QuantizedG>>,
}

/// A dense (non-factorized) FC layer as stored in a bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseLayerBundle {
    /// Weights `W (M, N)`, row-major.
    pub w: Tensor,
    /// Output bias (length `M`), if any.
    pub bias: Option<Vec<f32>>,
}

/// One step of the bundled model.
#[derive(Debug, Clone, PartialEq)]
pub enum BundleOp {
    /// A TT-compressed FC layer.
    Tt(TtLayerBundle),
    /// A dense FC fallback.
    Dense(DenseLayerBundle),
    /// Elementwise `max(0, x)`.
    Relu,
}

/// Per-layer outcome of an accuracy-budget compression: the rank the
/// weight-aware sweep ([`crate::dse::sweep_ranks`]) selected and the
/// measured TT-SVD relative reconstruction error that justified it.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoRankLayer {
    /// Selected (requested-ladder) rank — the deployed solution's label;
    /// the stored layout carries the achieved, possibly clipped, ranks.
    pub rank: u64,
    /// Measured relative Frobenius reconstruction error at that rank.
    pub rel_error: f64,
}

/// Record of an accuracy-budget compression ([`compress_auto`]): the
/// budget `ε` and, per FC layer in model order, the sweep's pick — `None`
/// for layers that stayed dense (below the size floor, or no swept rank
/// fit the budget). Persisted in META so [`verify`] can replay the auto
/// path instead of the fixed-rank path.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoRankInfo {
    /// The accuracy budget the compression was asked to meet.
    pub budget: f64,
    /// One entry per FC layer (same order as [`ModelBundle::shapes`]).
    pub layers: Vec<Option<AutoRankLayer>>,
}

/// A decoded (or freshly compressed) `.ttrv` bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelBundle {
    /// Model display name.
    pub name: String,
    /// `MachineSpec::name` the plans were compiled for; engines can only be
    /// built against the same machine.
    pub machine: String,
    /// Model input width.
    pub in_dim: usize,
    /// Model output width.
    pub out_dim: usize,
    /// Uniform rank requested at compression time.
    pub rank: u64,
    /// Seed of the deterministic demo weights (the repo stores no trained
    /// checkpoints; weights are seeded so `verify` can reproduce them).
    pub seed: u64,
    /// FC layer shapes `(n_in, m_out)` in model order.
    pub shapes: Vec<(u64, u64)>,
    /// The layer ops, model order.
    pub ops: Vec<BundleOp>,
    /// The embedded DSE report (one JSON object per FC layer).
    pub report: Json,
    /// Name of the microkernel [`tune_bundle`] measured its winners on
    /// (e.g. `"portable"`, `"avx2-fma"`) — persisted as the format-v3
    /// trailing field of the TUNE section. Observability only: serving
    /// re-probes the local host for dispatch, never this field. `None`
    /// when untuned or decoded from a pre-v3 bundle.
    pub tuned_kernel: Option<String>,
    /// Accuracy-budget compression record ([`compress_auto`]); `None` for
    /// fixed-rank bundles. Persisted as additive META keys, so fixed-rank
    /// bundles stay byte-identical to earlier format-v4 writers.
    pub auto: Option<AutoRankInfo>,
}

/// What to compress: a named stack of FC layers plus the demo-weight seed.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressSpec {
    /// Model display name.
    pub name: String,
    /// FC layer shapes `(n_in, m_out)`; consecutive layers must chain
    /// (`m_out` of layer i == `n_in` of layer i+1).
    pub shapes: Vec<(u64, u64)>,
    /// Uniform TT rank to request from the DSE selection.
    pub rank: u64,
    /// Seed for the deterministic demo weights.
    pub seed: u64,
}

impl CompressSpec {
    /// A spec for a zoo model's FC stack ([`models::model_by_name`]),
    /// repeated layers expanded in order.
    pub fn from_zoo(name: &str, rank: u64, seed: u64) -> Result<Self> {
        let arch = models::model_by_name(name)
            .ok_or_else(|| Error::config(format!("unknown zoo model '{name}'")))?;
        let mut shapes = Vec::new();
        for s in arch.fc_shapes() {
            for _ in 0..s.count {
                shapes.push((s.n, s.m));
            }
        }
        let spec = CompressSpec { name: arch.name.to_string(), shapes, rank, seed };
        spec.validate()?;
        Ok(spec)
    }

    /// Reject specs the compressor cannot realize as a sequential MLP.
    pub fn validate(&self) -> Result<()> {
        if self.shapes.is_empty() {
            return Err(Error::config(format!(
                "model '{}' has no FC layers to compress",
                self.name
            )));
        }
        if self.rank < 1 {
            return Err(Error::config("compress rank must be >= 1"));
        }
        // META stores the seed as a JSON number; beyond 2^53 it would not
        // survive the f64 round-trip and the written bundle could not be
        // read back — reject here instead of emitting an unreadable file
        if self.seed > (1u64 << 53) {
            return Err(Error::config(format!(
                "compress seed {} exceeds 2^53 (not exactly representable in bundle metadata)",
                self.seed
            )));
        }
        for w in self.shapes.windows(2) {
            let ((_, m_prev), (n_next, _)) = (w[0], w[1]);
            if m_prev != n_next {
                return Err(Error::config(format!(
                    "model '{}' FC layers do not chain: {} outputs then {} inputs",
                    self.name, m_prev, n_next
                )));
            }
        }
        Ok(())
    }
}

/// One FC layer's entry in the embedded DSE report.
fn layer_report(
    n: u64,
    m: u64,
    explored: Option<&TimedExplored>,
    selected: Option<&TimedSolution>,
    auto: Option<&AutoRankLayer>,
) -> Json {
    let mut fields = vec![
        ("n", Json::from(n as usize)),
        ("m", Json::from(m as usize)),
        ("routed", Json::from(if selected.is_some() { "tt" } else { "dense" })),
    ];
    if let Some(e) = explored {
        let c = &e.explored.counts;
        fields.push((
            "counts",
            Json::obj(vec![
                ("all", Json::from(c.all)),
                ("aligned", Json::from(c.aligned)),
                ("vectorized", Json::from(c.vectorized)),
                ("initial", Json::from(c.initial)),
                ("scalability", Json::from(c.scalability)),
                ("timed", Json::from(e.timed.len())),
            ]),
        ));
        fields.push(("dense_modeled_time_s", Json::from(e.dense_time_s)));
        fields.push(("frontier_total", Json::from(e.frontier.len())));
        fields.push((
            "frontier",
            Json::Arr(
                e.frontier
                    .iter()
                    .take(REPORT_FRONTIER_CAP)
                    .map(timed_solution_json)
                    .collect(),
            ),
        ));
    }
    fields.push((
        "selected",
        match selected {
            Some(s) => timed_solution_json(s),
            None => Json::Null,
        },
    ));
    if let Some(a) = auto {
        fields.push(("selected_rank", Json::from(a.rank as usize)));
        fields.push(("rel_error", Json::from(a.rel_error)));
    }
    Json::obj(fields)
}

/// TT-SVD the weights into the selected layout and compile/pack the chain —
/// the shared tail of the fixed-rank and accuracy-budget compression paths.
fn build_tt_layer(
    ex: &mut Executor,
    w: &Tensor,
    bias: Vec<f32>,
    sel: &TimedSolution,
) -> Result<TtLayerBundle> {
    let mut tt = tt_svd(w, sel.layout())?;
    tt.bias = Some(bias);
    let layout = tt.layout.clone();
    let chain = einsum_chain(&layout, 1);
    let mut plans = Vec::with_capacity(chain.len());
    let mut packed = Vec::with_capacity(chain.len());
    for (step, dims) in chain.iter().enumerate() {
        let plan = ex.plan(dims)?;
        packed.push(pack(&tt.cores[layout.d() - 1 - step], &plan)?);
        plans.push(plan);
    }
    Ok(TtLayerBundle {
        layout,
        packed,
        plans,
        bias: tt.bias,
        selected: sel.clone(),
        tuned: None, // `tune_bundle` fills this on request
        quant: None, // `quantize_bundle` fills this on request
    })
}

/// Run the offline half of the paper's pipeline for a whole FC stack:
/// per layer, route through the six-stage DSE engine, TT-SVD the (seeded,
/// deterministic) weights into the selected layout, compile the chain's
/// batch-1 plans and pack the cores as those plans require. The result is
/// a bundle ready to be written with [`super::write_bundle_file`] or
/// served directly via [`ModelBundle::build_engine`].
///
/// Deterministic end to end: the same `(spec, machine, cfg)` always
/// produces a byte-identical bundle — `verify` relies on this.
pub fn compress(spec: &CompressSpec, machine: &MachineSpec, cfg: &DseConfig) -> Result<ModelBundle> {
    compress_impl(spec, machine, cfg, None)
}

/// [`compress`] with the rank chosen per layer by the weight-aware rank
/// sweep under an accuracy budget, instead of `spec.rank` for every layer:
/// per FC layer, run the six-stage engine, sweep the rank ladder
/// (`DseConfig::rank_candidates`) over the layer's actual weights
/// ([`crate::dse::sweep_ranks`]), and deploy the fastest time-qualified
/// solution whose measured TT-SVD reconstruction error fits `budget`
/// ([`crate::dse::select_within_accuracy_budget`]). A layer where no swept
/// rank fits the budget stays dense — the same fallback the fixed-rank
/// path uses on selection failure. The bundle records the budget and every
/// per-layer pick in [`ModelBundle::auto`], so [`verify`] replays this
/// path; determinism is the same contract as [`compress`].
pub fn compress_auto(
    spec: &CompressSpec,
    machine: &MachineSpec,
    cfg: &DseConfig,
    budget: f64,
) -> Result<ModelBundle> {
    if !(budget.is_finite() && budget > 0.0) {
        return Err(Error::config(format!(
            "accuracy budget must be a finite value > 0, got {budget}"
        )));
    }
    compress_impl(spec, machine, cfg, Some(budget))
}

fn compress_impl(
    spec: &CompressSpec,
    machine: &MachineSpec,
    cfg: &DseConfig,
    auto_budget: Option<f64>,
) -> Result<ModelBundle> {
    spec.validate()?;
    cfg.validate()?;
    let mut rng = Rng::new(spec.seed);
    let mut ex = Executor::new(machine);
    let mut ops = Vec::new();
    let mut layers = Vec::new();
    let mut auto_layers = Vec::new();
    for (i, &(n, m)) in spec.shapes.iter().enumerate() {
        // demo weights: W then bias, drawn in layer order from the one
        // seeded stream (the reproducibility contract `verify` replays)
        let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
        let bias = rng.normal_vec(m as usize, 0.1);
        if let Some(budget) = auto_budget {
            if m < MIN_FC_DIM || n < MIN_FC_DIM {
                layers.push(layer_report(n, m, None, None, None));
                auto_layers.push(None);
                ops.push(BundleOp::Dense(DenseLayerBundle { w, bias: Some(bias) }));
            } else {
                let e = dse::explore_timed(m, n, machine, cfg);
                let sweep = dse::sweep_ranks(&e, &w, machine, cfg)?;
                match dse::select_within_accuracy_budget(&sweep, budget) {
                    Ok(sw) => {
                        let auto =
                            AutoRankLayer { rank: sw.timed.solution.rank, rel_error: sw.rel_error };
                        layers.push(layer_report(n, m, Some(&e), Some(&sw.timed), Some(&auto)));
                        ops.push(BundleOp::Tt(build_tt_layer(&mut ex, &w, bias, &sw.timed)?));
                        auto_layers.push(Some(auto));
                    }
                    Err(_) => {
                        layers.push(layer_report(n, m, Some(&e), None, None));
                        auto_layers.push(None);
                        ops.push(BundleOp::Dense(DenseLayerBundle { w, bias: Some(bias) }));
                    }
                }
            }
        } else {
            let (route, explored) = router::route_layer_explored(m, n, spec.rank, machine, cfg)?;
            match route {
                Route::Tt(sel) => {
                    layers.push(layer_report(n, m, explored.as_ref(), Some(&sel), None));
                    ops.push(BundleOp::Tt(build_tt_layer(&mut ex, &w, bias, &sel)?));
                }
                Route::Dense => {
                    layers.push(layer_report(n, m, explored.as_ref(), None, None));
                    ops.push(BundleOp::Dense(DenseLayerBundle { w, bias: Some(bias) }));
                }
            }
        }
        if i + 1 < spec.shapes.len() {
            ops.push(BundleOp::Relu);
        }
    }
    Ok(ModelBundle {
        name: spec.name.clone(),
        machine: machine.name.to_string(),
        in_dim: spec.shapes[0].0 as usize,
        out_dim: spec.shapes[spec.shapes.len() - 1].1 as usize,
        rank: spec.rank,
        seed: spec.seed,
        shapes: spec.shapes.clone(),
        ops,
        report: Json::Arr(layers),
        tuned_kernel: None, // `tune_bundle` fills this on request
        auto: auto_budget.map(|budget| AutoRankInfo { budget, layers: auto_layers }),
    })
}

/// Summary of a [`tune_bundle`] pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneReport {
    /// TT layers autotuned.
    pub layers: usize,
    /// Winning plans persisted (one per chain step across all layers).
    pub plans: usize,
}

/// Measured autotuning of every TT layer in a bundle: per layer, run
/// [`crate::kernels::Executor::tune_chain`] over the **stored** packed
/// cores at batch 1 and record the winners in
/// [`TtLayerBundle::tuned`] — what `ttrv compress --tune` persists as the
/// TUNE section. A layer already quantized ([`quantize_bundle`] before
/// `--tune`) tunes through
/// [`crate::kernels::Executor::tune_chain_q`] instead, ranking the int8
/// kernel roster over the int8 cores it will actually serve.
///
/// Plans are compiled for the bundle's target machine; the measurement
/// itself runs on the build host (like [`crate::dse::select::rerank_measured`]),
/// so the tuned RB/thread picks are host-measured re-rankings of the
/// target-planned candidate set. Tuning is measurement and therefore not
/// deterministic — [`verify`] compares bundles with the TUNE section
/// stripped, and serving output is bitwise-unchanged either way.
pub fn tune_bundle(
    bundle: &mut ModelBundle,
    machine: &MachineSpec,
    floor: &crate::util::timer::MeasureFloor,
) -> Result<TuneReport> {
    if machine.name != bundle.machine {
        return Err(Error::artifact(format!(
            "bundle was compiled for machine '{}', cannot tune for '{}'",
            bundle.machine, machine.name
        )));
    }
    let mut report = TuneReport { layers: 0, plans: 0 };
    for op in &mut bundle.ops {
        if let BundleOp::Tt(t) = op {
            let mut ex = Executor::new(machine);
            ex.preseed(&t.plans)?; // tune from the stored analytic plans
            let winners = match &t.quant {
                // a quantized layer serves the int8 chain, so rank the
                // int8 kernel roster over the cores it will actually run
                Some(q) => ex.tune_chain_q(&t.layout, 1, q, floor)?,
                None => ex.tune_chain(&t.layout, 1, &t.packed, floor)?,
            };
            report.layers += 1;
            report.plans += winners.len();
            t.tuned = Some(winners);
            // record which microkernel the winners were measured on (the
            // last layer's pick; kernels are ranked per chain, and on one
            // host every chain sees the same candidate set)
            bundle.tuned_kernel = Some(ex.kernel_name().to_string());
        }
    }
    Ok(report)
}

/// Calibration batch for the measured quantization-error check in
/// [`quantize_bundle`].
const QUANT_CALIB_BATCH: usize = 4;

/// Seed-mixing constant for the calibration inputs (a stream distinct
/// from both the demo weights and the verify replay batch).
const QUANT_CALIB_SEED: u64 = 0x14B1_7C57;

/// Summary of a [`quantize_bundle`] pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantReport {
    /// TT layers quantized (or measured, when not applied).
    pub layers: usize,
    /// Quantized cores across all layers.
    pub cores: usize,
    /// Worst measured max-relative-output-error across layers
    /// ([`crate::dse::measured_quant_error`]).
    pub max_rel_error: f64,
    /// Resident bytes of the f32 packed cores.
    pub f32_core_bytes: u64,
    /// Resident bytes of their int8 shadows (payload + scales).
    pub int8_core_bytes: u64,
    /// Whether the int8 cores were installed in the bundle. `false` only
    /// when a `max_error` budget was given and the measured error
    /// exceeded it — the bundle is then left untouched.
    pub applied: bool,
}

/// Int8-quantize every TT layer of a bundle: per layer, quantize the
/// stored packed cores per `m` slice ([`crate::kernels::quantize`]),
/// measure the resulting max-relative-output-error on seeded calibration
/// inputs ([`crate::dse::measured_quant_error`] — portable kernels, fully
/// deterministic), and install the int8 cores in
/// [`TtLayerBundle::quant`] — what `ttrv compress --quantize` persists as
/// the QUANT section. Each quantized layer's measured error and int8 byte
/// count are appended to its entry in the embedded DSE report.
///
/// With `max_error = Some(eps)`, the int8 cores ship only when the worst
/// layer's measured error fits the budget; otherwise the bundle is left
/// untouched and the report says so (`applied = false`). Unlike tuning,
/// quantization is deterministic end to end, so [`verify`] re-derives the
/// QUANT section from a fresh compression and byte-compares it like any
/// other section.
pub fn quantize_bundle(
    bundle: &mut ModelBundle,
    machine: &MachineSpec,
    max_error: Option<f64>,
) -> Result<QuantReport> {
    if machine.name != bundle.machine {
        return Err(Error::artifact(format!(
            "bundle was compiled for machine '{}', cannot quantize for '{}'",
            bundle.machine, machine.name
        )));
    }
    let mut report = QuantReport {
        layers: 0,
        cores: 0,
        max_rel_error: 0.0,
        f32_core_bytes: 0,
        int8_core_bytes: 0,
        applied: true,
    };
    // (op index, fc-layer index, cores, measured error) per TT layer —
    // staged so a blown budget leaves the bundle untouched
    let mut staged: Vec<(usize, usize, Vec<QuantizedG>, f64)> = Vec::new();
    let mut fc_idx = 0usize;
    for (i, op) in bundle.ops.iter().enumerate() {
        match op {
            BundleOp::Tt(t) => {
                let cores: Vec<QuantizedG> = t.packed.iter().map(quantize).collect();
                let err = crate::dse::measured_quant_error(
                    &t.layout,
                    &t.packed,
                    &cores,
                    machine,
                    QUANT_CALIB_BATCH,
                    bundle.seed ^ QUANT_CALIB_SEED,
                )?;
                report.layers += 1;
                report.cores += cores.len();
                report.max_rel_error = report.max_rel_error.max(err);
                report.f32_core_bytes += t.packed.iter().map(PackedG::bytes).sum::<usize>() as u64;
                report.int8_core_bytes +=
                    cores.iter().map(QuantizedG::bytes).sum::<usize>() as u64;
                staged.push((i, fc_idx, cores, err));
                fc_idx += 1;
            }
            BundleOp::Dense(_) => fc_idx += 1,
            BundleOp::Relu => {}
        }
    }
    if let Some(eps) = max_error {
        if report.max_rel_error > eps {
            report.applied = false;
            return Ok(report);
        }
    }
    for (i, fc, cores, err) in staged {
        let int8_bytes: usize = cores.iter().map(QuantizedG::bytes).sum();
        if let BundleOp::Tt(t) = &mut bundle.ops[i] {
            t.quant = Some(cores);
        }
        // annotate the layer's DSE report entry with the measured axis
        if let Json::Arr(layers) = &mut bundle.report {
            if let Some(Json::Obj(fields)) = layers.get_mut(fc) {
                fields.insert("quant_error".to_string(), Json::from(err));
                fields.insert("quant_core_bytes".to_string(), Json::from(int8_bytes));
            }
        }
    }
    Ok(report)
}

impl ModelBundle {
    /// The [`CompressSpec`] this bundle records (what `verify` re-runs).
    pub fn spec(&self) -> CompressSpec {
        CompressSpec {
            name: self.name.clone(),
            shapes: self.shapes.clone(),
            rank: self.rank,
            seed: self.seed,
        }
    }

    /// Stored parameter count (core/weight floats + biases).
    pub fn param_count(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                BundleOp::Tt(t) => {
                    // canonical core sizes (padding in PackedR is layout
                    // overhead, not parameters)
                    let cores: usize = (0..t.layout.d())
                        .map(|i| t.layout.core_shape(i).iter().product::<usize>())
                        .sum();
                    cores + t.bias.as_ref().map_or(0, Vec::len)
                }
                BundleOp::Dense(d) => d.w.numel() + d.bias.as_ref().map_or(0, Vec::len),
                BundleOp::Relu => 0,
            })
            .sum()
    }

    /// Number of TT-compressed layers.
    pub fn tt_layers(&self) -> usize {
        self.ops.iter().filter(|op| matches!(op, BundleOp::Tt(_))).count()
    }

    /// Approximate resident bytes of the engine [`build_engine`] would
    /// produce: packed core buffers (including layout padding, which *is*
    /// resident), dense weights, and biases. The serving registry charges
    /// this against its LRU cache budget without having to build the
    /// engine first; it matches
    /// [`ModelEngine::approx_bytes`](crate::coordinator::ModelEngine::approx_bytes)
    /// for the built engine.
    ///
    /// [`build_engine`]: Self::build_engine
    pub fn engine_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                BundleOp::Tt(t) => {
                    // a quantized layer serves its int8 shadow; the f32
                    // packed cores are not resident in the built engine
                    let cores: usize = match &t.quant {
                        Some(q) => q.iter().map(QuantizedG::bytes).sum(),
                        None => t.packed.iter().map(PackedG::bytes).sum(),
                    };
                    (cores + t.bias.as_ref().map_or(0, Vec::len) * 4) as u64
                }
                BundleOp::Dense(d) => {
                    ((d.w.numel() + d.bias.as_ref().map_or(0, Vec::len)) * 4) as u64
                }
                BundleOp::Relu => 0,
            })
            .sum()
    }

    /// Warm-start construction: stamp out a serving [`ModelEngine`]
    /// directly from the bundle — no DSE, no decomposition, no packing;
    /// every TT layer's executor starts with its chain plans pre-seeded.
    /// Layers carrying persisted measured plans ([`TtLayerBundle::tuned`])
    /// pre-seed those instead of the analytic plans — the output is
    /// bitwise-identical either way (tuning only moves RB factors and
    /// thread counts), only the speed differs. Layers carrying int8 cores
    /// ([`TtLayerBundle::quant`]) serve those instead of the f32 cores,
    /// on the int8 kernel family — ~4x fewer resident bytes, output
    /// within the quantization error the bundle's report records.
    ///
    /// The target must be the machine the bundle was compiled for
    /// (plans and packed layouts are machine-specific).
    pub fn build_engine(&self, machine: &MachineSpec) -> Result<ModelEngine> {
        if machine.name != self.machine {
            return Err(Error::artifact(format!(
                "bundle was compiled for machine '{}', cannot serve on '{}'",
                self.machine, machine.name
            )));
        }
        if self.ops.is_empty() {
            return Err(Error::artifact("bundle has no layer ops"));
        }
        let mut ops = Vec::with_capacity(self.ops.len());
        let mut width = self.in_dim;
        for (i, op) in self.ops.iter().enumerate() {
            match op {
                BundleOp::Tt(t) => {
                    if t.layout.n_total() as usize != width {
                        return Err(Error::artifact(format!(
                            "op {i}: TT layer expects {} inputs, model is at width {width}",
                            t.layout.n_total()
                        )));
                    }
                    width = t.layout.m_total() as usize;
                    let plans = t.tuned.as_deref().unwrap_or(&t.plans);
                    ops.push(LayerOp::Tt(match &t.quant {
                        Some(q) => TtFcEngine::from_quant_parts(
                            t.layout.clone(),
                            q.clone(),
                            plans,
                            t.bias.clone(),
                            machine,
                        )?,
                        None => TtFcEngine::from_parts(
                            t.layout.clone(),
                            t.packed.clone(),
                            plans,
                            t.bias.clone(),
                            machine,
                        )?,
                    }));
                }
                BundleOp::Dense(d) => {
                    if d.w.dims()[1] != width {
                        return Err(Error::artifact(format!(
                            "op {i}: dense layer expects {} inputs, model is at width {width}",
                            d.w.dims()[1]
                        )));
                    }
                    width = d.w.dims()[0];
                    ops.push(LayerOp::Dense(DenseFc::new(&d.w, d.bias.clone())?));
                }
                BundleOp::Relu => ops.push(LayerOp::Relu),
            }
        }
        if width != self.out_dim {
            return Err(Error::artifact(format!(
                "bundle declares out_dim {} but the op chain ends at width {width}",
                self.out_dim
            )));
        }
        Ok(ModelEngine::new(self.name.clone(), ops, self.in_dim, self.out_dim))
    }
}

/// Result summary of a successful [`verify`] run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    /// FC layers in the bundle.
    pub fc_layers: usize,
    /// How many of them are TT-compressed.
    pub tt_layers: usize,
    /// Size of the canonical re-encoding, in bytes.
    pub encoded_bytes: usize,
    /// Output values compared bitwise between the two engines.
    pub outputs_checked: usize,
}

/// Replay check of a decoded bundle: re-run [`compress`] from the bundle's
/// recorded `(shapes, rank, seed)`, require the fresh bundle to re-encode
/// **byte-identically**, then push a seeded input batch through both the
/// bundle-loaded engine and the freshly compressed one and require
/// **bitwise-identical** outputs. `cfg` must be the DSE config used at
/// compression time (the CLI always compresses with defaults).
///
/// The byte comparison runs with the TUNE section stripped: tuned plans
/// are *measured*, so a fresh compression cannot reproduce them byte for
/// byte — but the replay half still runs the loaded engine on its tuned
/// plans, so verify also re-proves that measured plans leave every output
/// bit where the analytic plans put it.
///
/// The QUANT section, by contrast, is **not** stripped: quantization is
/// deterministic, so when the loaded bundle carries int8 cores the fresh
/// compression is re-quantized ([`quantize_bundle`], no budget) and the
/// QUANT bytes — scales, payloads and the report's error annotations —
/// must match exactly. The replay then runs both engines on the int8
/// path and still requires bitwise-identical outputs.
pub fn verify(bundle: &ModelBundle, machine: &MachineSpec, cfg: &DseConfig) -> Result<VerifyReport> {
    // a machine mismatch must read as exactly that, not as a byte-level
    // "does not match a fresh compression" corruption diagnosis
    if machine.name != bundle.machine {
        return Err(Error::artifact(format!(
            "bundle was compiled for machine '{}', verifying against '{}'",
            bundle.machine, machine.name
        )));
    }
    // an auto-rank bundle must be replayed through the accuracy-budget
    // path — re-compressing at the fixed spec rank would reproduce a
    // different (and legitimately so) set of layers
    let mut fresh = match &bundle.auto {
        Some(a) => compress_auto(&bundle.spec(), machine, cfg, a.budget)?,
        None => compress(&bundle.spec(), machine, cfg)?,
    };
    if bundle.ops.iter().any(|op| matches!(op, BundleOp::Tt(t) if t.quant.is_some())) {
        quantize_bundle(&mut fresh, machine, None)?;
    }
    let mut sans_tune = bundle.clone();
    for op in &mut sans_tune.ops {
        if let BundleOp::Tt(t) = op {
            t.tuned = None;
        }
    }
    sans_tune.tuned_kernel = None;
    let loaded_bytes = super::write_bundle(&sans_tune);
    let fresh_bytes = super::write_bundle(&fresh);
    if loaded_bytes != fresh_bytes {
        return Err(Error::artifact(format!(
            "bundle does not match a fresh compression of {} (rank {}, seed {}): \
             {} vs {} canonical bytes{}",
            bundle.name,
            bundle.rank,
            bundle.seed,
            loaded_bytes.len(),
            fresh_bytes.len(),
            if loaded_bytes.len() == fresh_bytes.len() { ", content differs" } else { "" },
        )));
    }
    let mut from_artifact = bundle.build_engine(machine)?;
    let mut from_scratch = fresh.build_engine(machine)?;
    let batch = 4usize;
    let mut rng = Rng::new(bundle.seed ^ 0xA57F_AC75);
    let x = Tensor::randn(vec![batch, bundle.in_dim], 1.0, &mut rng);
    let a = from_artifact.forward(&x)?;
    let b = from_scratch.forward(&x)?;
    for (i, (va, vb)) in a.data().iter().zip(b.data()).enumerate() {
        if va.to_bits() != vb.to_bits() {
            return Err(Error::artifact(format!(
                "artifact-served output diverges from fresh compression at element {i}: \
                 {va} vs {vb}"
            )));
        }
    }
    Ok(VerifyReport {
        fc_layers: bundle.shapes.len(),
        tt_layers: bundle.tt_layers(),
        encoded_bytes: loaded_bytes.len(),
        outputs_checked: a.numel(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    fn lenet_spec() -> CompressSpec {
        CompressSpec::from_zoo("lenet300", 8, 42).unwrap()
    }

    #[test]
    fn zoo_spec_expands_and_validates() {
        let spec = lenet_spec();
        assert_eq!(spec.shapes, vec![(784, 300), (300, 100), (100, 10)]);
        assert_eq!(spec.name, "LeNet300");
        assert!(CompressSpec::from_zoo("no-such-model", 8, 0).is_err());
        // GPT FC stacks do not chain into an MLP
        let bad = CompressSpec {
            name: "x".into(),
            shapes: vec![(10, 20), (30, 5)],
            rank: 8,
            seed: 0,
        };
        assert!(bad.validate().is_err());
        let empty = CompressSpec { name: "x".into(), shapes: vec![], rank: 8, seed: 0 };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn compress_routes_like_the_examples_and_is_deterministic() {
        let spec = lenet_spec();
        let b1 = compress(&spec, &k1(), &DseConfig::default()).unwrap();
        let b2 = compress(&spec, &k1(), &DseConfig::default()).unwrap();
        assert_eq!(b1, b2);
        // 784->300 and 300->100 factorize; the 10-class head stays dense
        assert_eq!(b1.tt_layers(), 2);
        assert_eq!(b1.ops.len(), 5); // Tt, Relu, Tt, Relu, Dense
        assert!(matches!(b1.ops[4], BundleOp::Dense(_)));
        assert_eq!(b1.in_dim, 784);
        assert_eq!(b1.out_dim, 10);
        // compression actually compresses
        let dense_params: usize = spec
            .shapes
            .iter()
            .map(|&(n, m)| (n * m + m) as usize)
            .sum();
        assert!(b1.param_count() < dense_params / 2);
        // report carries one entry per FC layer
        assert_eq!(b1.report.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn built_engine_matches_direct_construction_bitwise() {
        let bundle = compress(&lenet_spec(), &k1(), &DseConfig::default()).unwrap();
        let mut e1 = bundle.build_engine(&k1()).unwrap();
        let mut e2 = bundle.build_engine(&k1()).unwrap();
        let mut rng = Rng::new(9);
        for batch in [1usize, 3] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let a = e1.forward(&x).unwrap();
            let b = e2.forward(&x).unwrap();
            assert_eq!(a.dims(), &[batch, 10]);
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn build_engine_rejects_wrong_machine_and_broken_chains() {
        let bundle = compress(&lenet_spec(), &k1(), &DseConfig::default()).unwrap();
        let err = bundle.build_engine(&MachineSpec::host()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");

        let mut broken = bundle.clone();
        broken.out_dim = 11;
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
        let mut broken = bundle.clone();
        broken.in_dim = 100;
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
        let mut broken = bundle;
        broken.ops.clear();
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
    }

    #[test]
    fn quantize_bundle_installs_int8_within_budget_and_verifies() {
        let cfg = DseConfig::default();
        let mut bundle = compress(&lenet_spec(), &k1(), &cfg).unwrap();
        let f32_engine_bytes = bundle.engine_bytes();
        let report = quantize_bundle(&mut bundle, &k1(), None).unwrap();
        assert!(report.applied);
        assert_eq!(report.layers, 2);
        assert!(report.cores > 0);
        assert!(
            report.max_rel_error > 0.0 && report.max_rel_error < 0.05,
            "measured error: {}",
            report.max_rel_error
        );
        // the tentpole acceptance bar: int8 core bytes shrink >= 3.5x,
        // and the registry-visible engine bytes shrink with them
        assert!(
            report.f32_core_bytes as f64 / report.int8_core_bytes as f64 >= 3.5,
            "{} vs {} core bytes",
            report.f32_core_bytes,
            report.int8_core_bytes
        );
        assert!(bundle.engine_bytes() < f32_engine_bytes / 3);
        // the report JSON now carries the error axis per TT layer
        let layers = bundle.report.as_arr().unwrap();
        assert!(layers[0].get("quant_error").is_some());
        assert!(layers[0].get("quant_core_bytes").is_some());
        // quantization is deterministic: verify re-derives the QUANT
        // section from a fresh compression and byte-compares it
        let vr = verify(&bundle, &k1(), &cfg).unwrap();
        assert_eq!(vr.tt_layers, 2);
        // wrong machine is a typed artifact error
        let err = quantize_bundle(&mut bundle, &MachineSpec::host(), None).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
    }

    #[test]
    fn quantize_budget_gates_shipping_int8() {
        let cfg = DseConfig::default();
        let mut bundle = compress(&lenet_spec(), &k1(), &cfg).unwrap();
        // measure once to learn the actual error, then re-run under a
        // budget below it: the bundle must come back untouched
        let probe = quantize_bundle(&mut bundle.clone(), &k1(), None).unwrap();
        let tight = probe.max_rel_error / 10.0;
        let report = quantize_bundle(&mut bundle, &k1(), Some(tight)).unwrap();
        assert!(!report.applied);
        assert_eq!(report.max_rel_error, probe.max_rel_error);
        assert!(bundle
            .ops
            .iter()
            .all(|op| !matches!(op, BundleOp::Tt(t) if t.quant.is_some())));
        assert!(bundle.report.as_arr().unwrap()[0].get("quant_error").is_none());
        // a generous budget ships
        let report = quantize_bundle(&mut bundle, &k1(), Some(0.5)).unwrap();
        assert!(report.applied);
        assert!(bundle
            .ops
            .iter()
            .any(|op| matches!(op, BundleOp::Tt(t) if t.quant.is_some())));
    }

    #[test]
    fn compress_auto_records_sweep_picks_and_verifies() {
        // small ladder / single swept shape: the accuracy sweep re-runs
        // TT-SVD per candidate, which is expensive in debug builds
        let cfg = DseConfig {
            rank_candidates: vec![2, 8],
            sweep_shapes: 1,
            ..Default::default()
        };
        let spec = CompressSpec {
            name: "auto-one".into(),
            shapes: vec![(784, 300)],
            rank: 8,
            seed: 42,
        };
        // randn weights concentrate energy across the whole spectrum:
        // rank 8 on the balanced 420x560 unfolding truncates to ~0.97
        // relative error and rank 2 to ~0.99, so a 0.98 budget admits
        // exactly the rank-8 candidates of the ladder
        let bundle = compress_auto(&spec, &k1(), &cfg, 0.98).unwrap();
        assert_eq!(bundle.tt_layers(), 1);
        let auto = bundle.auto.as_ref().expect("auto record");
        assert_eq!(auto.budget, 0.98);
        assert_eq!(auto.layers.len(), 1);
        let layer = auto.layers[0].as_ref().expect("swept pick");
        assert_eq!(layer.rank, 8, "a 0.98 budget must exclude the rank-2 candidates");
        assert!(layer.rel_error.is_finite() && layer.rel_error <= 0.98);
        // the embedded report carries the pick alongside the classic fields
        let entry = &bundle.report.as_arr().unwrap()[0];
        assert_eq!(
            entry.get("selected_rank"),
            Some(&Json::from(layer.rank as usize))
        );
        assert_eq!(entry.get("rel_error"), Some(&Json::from(layer.rel_error)));
        // verify replays the accuracy-budget path (byte-compare + bitwise
        // outputs), which also proves compress_auto is deterministic
        let vr = verify(&bundle, &k1(), &cfg).unwrap();
        assert_eq!(vr.tt_layers, 1);
        // rejecting the budget is a config error, not a panic
        assert!(matches!(
            compress_auto(&spec, &k1(), &cfg, 0.0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            compress_auto(&spec, &k1(), &cfg, f64::NAN),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn compress_auto_impossible_budget_stays_dense() {
        let cfg = DseConfig {
            rank_candidates: vec![8],
            sweep_shapes: 1,
            ..Default::default()
        };
        let spec = CompressSpec {
            name: "auto-dense".into(),
            shapes: vec![(784, 300)],
            rank: 8,
            seed: 42,
        };
        // randn weights are far from low TT rank: a vanishing budget is
        // unsatisfiable, so the layer falls back to dense — recorded as a
        // None pick, never an error
        let bundle = compress_auto(&spec, &k1(), &cfg, 1e-12).unwrap();
        assert_eq!(bundle.tt_layers(), 0);
        let auto = bundle.auto.as_ref().unwrap();
        assert_eq!(auto.layers, vec![None]);
        assert!(bundle.report.as_arr().unwrap()[0].get("selected_rank").is_none());
    }

    #[test]
    fn verify_accepts_fresh_and_rejects_tampered() {
        let cfg = DseConfig::default();
        let bundle = compress(&lenet_spec(), &k1(), &cfg).unwrap();
        let report = verify(&bundle, &k1(), &cfg).unwrap();
        assert_eq!(report.fc_layers, 3);
        assert_eq!(report.tt_layers, 2);
        assert_eq!(report.outputs_checked, 4 * 10);

        // a tampered weight is caught by the byte comparison
        let mut tampered = bundle;
        for op in &mut tampered.ops {
            if let BundleOp::Tt(t) = op {
                t.packed[0].data[0] += 1.0;
                break;
            }
        }
        assert!(matches!(verify(&tampered, &k1(), &cfg), Err(Error::Artifact(_))));
    }
}
