//! The in-memory form of a `.ttrv` bundle and the two pipelines around it:
//! **compress** (DSE route → TT-SVD → compile → pack → bundle) and
//! **warm-start** (bundle → engines with pre-seeded plan caches, zero DSE
//! and zero decomposition at load time).
//!
//! A bundle is plain data — layouts, packed core buffers, compiled plans,
//! dense weights, biases — never live engines, so it can be written,
//! diffed and round-tripped without touching executor state. Engines are
//! stamped out on demand by [`ModelBundle::build_engine`].

use crate::baselines::dense::DenseFc;
use crate::compiler::OptimizationPlan;
use crate::config::DseConfig;
use crate::coordinator::router::{self, Route};
use crate::coordinator::{LayerOp, ModelEngine, TtFcEngine};
use crate::dse::report::timed_solution_json;
use crate::dse::{TimedExplored, TimedSolution};
use crate::error::{Error, Result};
use crate::kernels::{pack, Executor, PackedG};
use crate::machine::MachineSpec;
use crate::models;
use crate::tensor::Tensor;
use crate::ttd::cost::einsum_chain;
use crate::ttd::decompose::tt_svd;
use crate::ttd::TtLayout;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// Frontier entries embedded per layer in the bundle's DSE report; the
/// report records the full frontier size alongside so the cap is never a
/// silent truncation.
const REPORT_FRONTIER_CAP: usize = 32;

/// A TT-compressed FC layer as stored in a bundle: everything the serving
/// engine needs, already in execution form.
#[derive(Debug, Clone, PartialEq)]
pub struct TtLayerBundle {
    /// The layout the stored cores realize (achieved TT-SVD ranks, which
    /// the decomposition may have clipped below the selected solution's).
    pub layout: TtLayout,
    /// Packed core per chain step, processing order (t = d-1 .. 0), in the
    /// `G` layout each step's plan chose.
    pub packed: Vec<PackedG>,
    /// Compiled batch-1 plan per chain step (processing order) — pre-seeds
    /// the executor's plan cache at load.
    pub plans: Vec<OptimizationPlan>,
    /// Output bias (length `M`), if any.
    pub bias: Option<Vec<f32>>,
    /// The DSE-selected, time-qualified solution this layer deployed.
    pub selected: TimedSolution,
    /// Measured-autotuned batch-1 plans (same chain order/dims as `plans`,
    /// RB factors / thread counts re-ranked by measurement —
    /// [`crate::kernels::Executor::tune_chain`]). Persisted as the
    /// optional TUNE section; `None` = serve with the analytic `plans`.
    /// Tuned plans never change the packed `G` layout or any result bit.
    pub tuned: Option<Vec<OptimizationPlan>>,
}

/// A dense (non-factorized) FC layer as stored in a bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseLayerBundle {
    /// Weights `W (M, N)`, row-major.
    pub w: Tensor,
    /// Output bias (length `M`), if any.
    pub bias: Option<Vec<f32>>,
}

/// One step of the bundled model.
#[derive(Debug, Clone, PartialEq)]
pub enum BundleOp {
    /// A TT-compressed FC layer.
    Tt(TtLayerBundle),
    /// A dense FC fallback.
    Dense(DenseLayerBundle),
    /// Elementwise `max(0, x)`.
    Relu,
}

/// A decoded (or freshly compressed) `.ttrv` bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelBundle {
    /// Model display name.
    pub name: String,
    /// `MachineSpec::name` the plans were compiled for; engines can only be
    /// built against the same machine.
    pub machine: String,
    /// Model input width.
    pub in_dim: usize,
    /// Model output width.
    pub out_dim: usize,
    /// Uniform rank requested at compression time.
    pub rank: u64,
    /// Seed of the deterministic demo weights (the repo stores no trained
    /// checkpoints; weights are seeded so `verify` can reproduce them).
    pub seed: u64,
    /// FC layer shapes `(n_in, m_out)` in model order.
    pub shapes: Vec<(u64, u64)>,
    /// The layer ops, model order.
    pub ops: Vec<BundleOp>,
    /// The embedded DSE report (one JSON object per FC layer).
    pub report: Json,
    /// Name of the microkernel [`tune_bundle`] measured its winners on
    /// (e.g. `"portable"`, `"avx2-fma"`) — persisted as the format-v3
    /// trailing field of the TUNE section. Observability only: serving
    /// re-probes the local host for dispatch, never this field. `None`
    /// when untuned or decoded from a pre-v3 bundle.
    pub tuned_kernel: Option<String>,
}

/// What to compress: a named stack of FC layers plus the demo-weight seed.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressSpec {
    /// Model display name.
    pub name: String,
    /// FC layer shapes `(n_in, m_out)`; consecutive layers must chain
    /// (`m_out` of layer i == `n_in` of layer i+1).
    pub shapes: Vec<(u64, u64)>,
    /// Uniform TT rank to request from the DSE selection.
    pub rank: u64,
    /// Seed for the deterministic demo weights.
    pub seed: u64,
}

impl CompressSpec {
    /// A spec for a zoo model's FC stack ([`models::model_by_name`]),
    /// repeated layers expanded in order.
    pub fn from_zoo(name: &str, rank: u64, seed: u64) -> Result<Self> {
        let arch = models::model_by_name(name)
            .ok_or_else(|| Error::config(format!("unknown zoo model '{name}'")))?;
        let mut shapes = Vec::new();
        for s in arch.fc_shapes() {
            for _ in 0..s.count {
                shapes.push((s.n, s.m));
            }
        }
        let spec = CompressSpec { name: arch.name.to_string(), shapes, rank, seed };
        spec.validate()?;
        Ok(spec)
    }

    /// Reject specs the compressor cannot realize as a sequential MLP.
    pub fn validate(&self) -> Result<()> {
        if self.shapes.is_empty() {
            return Err(Error::config(format!(
                "model '{}' has no FC layers to compress",
                self.name
            )));
        }
        if self.rank < 1 {
            return Err(Error::config("compress rank must be >= 1"));
        }
        // META stores the seed as a JSON number; beyond 2^53 it would not
        // survive the f64 round-trip and the written bundle could not be
        // read back — reject here instead of emitting an unreadable file
        if self.seed > (1u64 << 53) {
            return Err(Error::config(format!(
                "compress seed {} exceeds 2^53 (not exactly representable in bundle metadata)",
                self.seed
            )));
        }
        for w in self.shapes.windows(2) {
            let ((_, m_prev), (n_next, _)) = (w[0], w[1]);
            if m_prev != n_next {
                return Err(Error::config(format!(
                    "model '{}' FC layers do not chain: {} outputs then {} inputs",
                    self.name, m_prev, n_next
                )));
            }
        }
        Ok(())
    }
}

/// One FC layer's entry in the embedded DSE report.
fn layer_report(
    n: u64,
    m: u64,
    explored: Option<&TimedExplored>,
    selected: Option<&TimedSolution>,
) -> Json {
    let mut fields = vec![
        ("n", Json::from(n as usize)),
        ("m", Json::from(m as usize)),
        ("routed", Json::from(if selected.is_some() { "tt" } else { "dense" })),
    ];
    if let Some(e) = explored {
        let c = &e.explored.counts;
        fields.push((
            "counts",
            Json::obj(vec![
                ("all", Json::from(c.all)),
                ("aligned", Json::from(c.aligned)),
                ("vectorized", Json::from(c.vectorized)),
                ("initial", Json::from(c.initial)),
                ("scalability", Json::from(c.scalability)),
                ("timed", Json::from(e.timed.len())),
            ]),
        ));
        fields.push(("dense_modeled_time_s", Json::from(e.dense_time_s)));
        fields.push(("frontier_total", Json::from(e.frontier.len())));
        fields.push((
            "frontier",
            Json::Arr(
                e.frontier
                    .iter()
                    .take(REPORT_FRONTIER_CAP)
                    .map(timed_solution_json)
                    .collect(),
            ),
        ));
    }
    fields.push((
        "selected",
        match selected {
            Some(s) => timed_solution_json(s),
            None => Json::Null,
        },
    ));
    Json::obj(fields)
}

/// Run the offline half of the paper's pipeline for a whole FC stack:
/// per layer, route through the six-stage DSE engine, TT-SVD the (seeded,
/// deterministic) weights into the selected layout, compile the chain's
/// batch-1 plans and pack the cores as those plans require. The result is
/// a bundle ready to be written with [`super::write_bundle_file`] or
/// served directly via [`ModelBundle::build_engine`].
///
/// Deterministic end to end: the same `(spec, machine, cfg)` always
/// produces a byte-identical bundle — `verify` relies on this.
pub fn compress(spec: &CompressSpec, machine: &MachineSpec, cfg: &DseConfig) -> Result<ModelBundle> {
    spec.validate()?;
    cfg.validate()?;
    let mut rng = Rng::new(spec.seed);
    let mut ex = Executor::new(machine);
    let mut ops = Vec::new();
    let mut layers = Vec::new();
    for (i, &(n, m)) in spec.shapes.iter().enumerate() {
        // demo weights: W then bias, drawn in layer order from the one
        // seeded stream (the reproducibility contract `verify` replays)
        let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
        let bias = rng.normal_vec(m as usize, 0.1);
        let (route, explored) = router::route_layer_explored(m, n, spec.rank, machine, cfg)?;
        match route {
            Route::Tt(sel) => {
                let mut tt = tt_svd(&w, sel.layout())?;
                tt.bias = Some(bias);
                let layout = tt.layout.clone();
                let chain = einsum_chain(&layout, 1);
                let mut plans = Vec::with_capacity(chain.len());
                let mut packed = Vec::with_capacity(chain.len());
                for (step, dims) in chain.iter().enumerate() {
                    let plan = ex.plan(dims)?;
                    packed.push(pack(&tt.cores[layout.d() - 1 - step], &plan)?);
                    plans.push(plan);
                }
                layers.push(layer_report(n, m, explored.as_ref(), Some(&sel)));
                ops.push(BundleOp::Tt(TtLayerBundle {
                    layout,
                    packed,
                    plans,
                    bias: tt.bias,
                    selected: sel,
                    tuned: None, // `tune_bundle` fills this on request
                }));
            }
            Route::Dense => {
                layers.push(layer_report(n, m, explored.as_ref(), None));
                ops.push(BundleOp::Dense(DenseLayerBundle { w, bias: Some(bias) }));
            }
        }
        if i + 1 < spec.shapes.len() {
            ops.push(BundleOp::Relu);
        }
    }
    Ok(ModelBundle {
        name: spec.name.clone(),
        machine: machine.name.to_string(),
        in_dim: spec.shapes[0].0 as usize,
        out_dim: spec.shapes[spec.shapes.len() - 1].1 as usize,
        rank: spec.rank,
        seed: spec.seed,
        shapes: spec.shapes.clone(),
        ops,
        report: Json::Arr(layers),
        tuned_kernel: None, // `tune_bundle` fills this on request
    })
}

/// Summary of a [`tune_bundle`] pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneReport {
    /// TT layers autotuned.
    pub layers: usize,
    /// Winning plans persisted (one per chain step across all layers).
    pub plans: usize,
}

/// Measured autotuning of every TT layer in a bundle: per layer, run
/// [`crate::kernels::Executor::tune_chain`] over the **stored** packed
/// cores at batch 1 and record the winners in
/// [`TtLayerBundle::tuned`] — what `ttrv compress --tune` persists as the
/// TUNE section.
///
/// Plans are compiled for the bundle's target machine; the measurement
/// itself runs on the build host (like [`crate::dse::select::rerank_measured`]),
/// so the tuned RB/thread picks are host-measured re-rankings of the
/// target-planned candidate set. Tuning is measurement and therefore not
/// deterministic — [`verify`] compares bundles with the TUNE section
/// stripped, and serving output is bitwise-unchanged either way.
pub fn tune_bundle(
    bundle: &mut ModelBundle,
    machine: &MachineSpec,
    floor: &crate::util::timer::MeasureFloor,
) -> Result<TuneReport> {
    if machine.name != bundle.machine {
        return Err(Error::artifact(format!(
            "bundle was compiled for machine '{}', cannot tune for '{}'",
            bundle.machine, machine.name
        )));
    }
    let mut report = TuneReport { layers: 0, plans: 0 };
    for op in &mut bundle.ops {
        if let BundleOp::Tt(t) = op {
            let mut ex = Executor::new(machine);
            ex.preseed(&t.plans); // tune from the stored analytic plans
            let winners = ex.tune_chain(&t.layout, 1, &t.packed, floor)?;
            report.layers += 1;
            report.plans += winners.len();
            t.tuned = Some(winners);
            // record which microkernel the winners were measured on (the
            // last layer's pick; kernels are ranked per chain, and on one
            // host every chain sees the same candidate set)
            bundle.tuned_kernel = Some(ex.kernel_name().to_string());
        }
    }
    Ok(report)
}

impl ModelBundle {
    /// The [`CompressSpec`] this bundle records (what `verify` re-runs).
    pub fn spec(&self) -> CompressSpec {
        CompressSpec {
            name: self.name.clone(),
            shapes: self.shapes.clone(),
            rank: self.rank,
            seed: self.seed,
        }
    }

    /// Stored parameter count (core/weight floats + biases).
    pub fn param_count(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                BundleOp::Tt(t) => {
                    // canonical core sizes (padding in PackedR is layout
                    // overhead, not parameters)
                    let cores: usize = (0..t.layout.d())
                        .map(|i| t.layout.core_shape(i).iter().product::<usize>())
                        .sum();
                    cores + t.bias.as_ref().map_or(0, Vec::len)
                }
                BundleOp::Dense(d) => d.w.numel() + d.bias.as_ref().map_or(0, Vec::len),
                BundleOp::Relu => 0,
            })
            .sum()
    }

    /// Number of TT-compressed layers.
    pub fn tt_layers(&self) -> usize {
        self.ops.iter().filter(|op| matches!(op, BundleOp::Tt(_))).count()
    }

    /// Approximate resident bytes of the engine [`build_engine`] would
    /// produce: packed core buffers (including layout padding, which *is*
    /// resident), dense weights, and biases. The serving registry charges
    /// this against its LRU cache budget without having to build the
    /// engine first; it matches
    /// [`ModelEngine::approx_bytes`](crate::coordinator::ModelEngine::approx_bytes)
    /// for the built engine.
    ///
    /// [`build_engine`]: Self::build_engine
    pub fn engine_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                BundleOp::Tt(t) => {
                    let cores: usize = t.packed.iter().map(PackedG::bytes).sum();
                    (cores + t.bias.as_ref().map_or(0, Vec::len) * 4) as u64
                }
                BundleOp::Dense(d) => {
                    ((d.w.numel() + d.bias.as_ref().map_or(0, Vec::len)) * 4) as u64
                }
                BundleOp::Relu => 0,
            })
            .sum()
    }

    /// Warm-start construction: stamp out a serving [`ModelEngine`]
    /// directly from the bundle — no DSE, no decomposition, no packing;
    /// every TT layer's executor starts with its chain plans pre-seeded.
    /// Layers carrying persisted measured plans ([`TtLayerBundle::tuned`])
    /// pre-seed those instead of the analytic plans — the output is
    /// bitwise-identical either way (tuning only moves RB factors and
    /// thread counts), only the speed differs.
    ///
    /// The target must be the machine the bundle was compiled for
    /// (plans and packed layouts are machine-specific).
    pub fn build_engine(&self, machine: &MachineSpec) -> Result<ModelEngine> {
        if machine.name != self.machine {
            return Err(Error::artifact(format!(
                "bundle was compiled for machine '{}', cannot serve on '{}'",
                self.machine, machine.name
            )));
        }
        if self.ops.is_empty() {
            return Err(Error::artifact("bundle has no layer ops"));
        }
        let mut ops = Vec::with_capacity(self.ops.len());
        let mut width = self.in_dim;
        for (i, op) in self.ops.iter().enumerate() {
            match op {
                BundleOp::Tt(t) => {
                    if t.layout.n_total() as usize != width {
                        return Err(Error::artifact(format!(
                            "op {i}: TT layer expects {} inputs, model is at width {width}",
                            t.layout.n_total()
                        )));
                    }
                    width = t.layout.m_total() as usize;
                    ops.push(LayerOp::Tt(TtFcEngine::from_parts(
                        t.layout.clone(),
                        t.packed.clone(),
                        t.tuned.as_deref().unwrap_or(&t.plans),
                        t.bias.clone(),
                        machine,
                    )?));
                }
                BundleOp::Dense(d) => {
                    if d.w.dims()[1] != width {
                        return Err(Error::artifact(format!(
                            "op {i}: dense layer expects {} inputs, model is at width {width}",
                            d.w.dims()[1]
                        )));
                    }
                    width = d.w.dims()[0];
                    ops.push(LayerOp::Dense(DenseFc::new(&d.w, d.bias.clone())?));
                }
                BundleOp::Relu => ops.push(LayerOp::Relu),
            }
        }
        if width != self.out_dim {
            return Err(Error::artifact(format!(
                "bundle declares out_dim {} but the op chain ends at width {width}",
                self.out_dim
            )));
        }
        Ok(ModelEngine::new(self.name.clone(), ops, self.in_dim, self.out_dim))
    }
}

/// Result summary of a successful [`verify`] run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    /// FC layers in the bundle.
    pub fc_layers: usize,
    /// How many of them are TT-compressed.
    pub tt_layers: usize,
    /// Size of the canonical re-encoding, in bytes.
    pub encoded_bytes: usize,
    /// Output values compared bitwise between the two engines.
    pub outputs_checked: usize,
}

/// Replay check of a decoded bundle: re-run [`compress`] from the bundle's
/// recorded `(shapes, rank, seed)`, require the fresh bundle to re-encode
/// **byte-identically**, then push a seeded input batch through both the
/// bundle-loaded engine and the freshly compressed one and require
/// **bitwise-identical** outputs. `cfg` must be the DSE config used at
/// compression time (the CLI always compresses with defaults).
///
/// The byte comparison runs with the TUNE section stripped: tuned plans
/// are *measured*, so a fresh compression cannot reproduce them byte for
/// byte — but the replay half still runs the loaded engine on its tuned
/// plans, so verify also re-proves that measured plans leave every output
/// bit where the analytic plans put it.
pub fn verify(bundle: &ModelBundle, machine: &MachineSpec, cfg: &DseConfig) -> Result<VerifyReport> {
    // a machine mismatch must read as exactly that, not as a byte-level
    // "does not match a fresh compression" corruption diagnosis
    if machine.name != bundle.machine {
        return Err(Error::artifact(format!(
            "bundle was compiled for machine '{}', verifying against '{}'",
            bundle.machine, machine.name
        )));
    }
    let fresh = compress(&bundle.spec(), machine, cfg)?;
    let mut sans_tune = bundle.clone();
    for op in &mut sans_tune.ops {
        if let BundleOp::Tt(t) = op {
            t.tuned = None;
        }
    }
    sans_tune.tuned_kernel = None;
    let loaded_bytes = super::write_bundle(&sans_tune);
    let fresh_bytes = super::write_bundle(&fresh);
    if loaded_bytes != fresh_bytes {
        return Err(Error::artifact(format!(
            "bundle does not match a fresh compression of {} (rank {}, seed {}): \
             {} vs {} canonical bytes{}",
            bundle.name,
            bundle.rank,
            bundle.seed,
            loaded_bytes.len(),
            fresh_bytes.len(),
            if loaded_bytes.len() == fresh_bytes.len() { ", content differs" } else { "" },
        )));
    }
    let mut from_artifact = bundle.build_engine(machine)?;
    let mut from_scratch = fresh.build_engine(machine)?;
    let batch = 4usize;
    let mut rng = Rng::new(bundle.seed ^ 0xA57F_AC75);
    let x = Tensor::randn(vec![batch, bundle.in_dim], 1.0, &mut rng);
    let a = from_artifact.forward(&x)?;
    let b = from_scratch.forward(&x)?;
    for (i, (va, vb)) in a.data().iter().zip(b.data()).enumerate() {
        if va.to_bits() != vb.to_bits() {
            return Err(Error::artifact(format!(
                "artifact-served output diverges from fresh compression at element {i}: \
                 {va} vs {vb}"
            )));
        }
    }
    Ok(VerifyReport {
        fc_layers: bundle.shapes.len(),
        tt_layers: bundle.tt_layers(),
        encoded_bytes: loaded_bytes.len(),
        outputs_checked: a.numel(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    fn lenet_spec() -> CompressSpec {
        CompressSpec::from_zoo("lenet300", 8, 42).unwrap()
    }

    #[test]
    fn zoo_spec_expands_and_validates() {
        let spec = lenet_spec();
        assert_eq!(spec.shapes, vec![(784, 300), (300, 100), (100, 10)]);
        assert_eq!(spec.name, "LeNet300");
        assert!(CompressSpec::from_zoo("no-such-model", 8, 0).is_err());
        // GPT FC stacks do not chain into an MLP
        let bad = CompressSpec {
            name: "x".into(),
            shapes: vec![(10, 20), (30, 5)],
            rank: 8,
            seed: 0,
        };
        assert!(bad.validate().is_err());
        let empty = CompressSpec { name: "x".into(), shapes: vec![], rank: 8, seed: 0 };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn compress_routes_like_the_examples_and_is_deterministic() {
        let spec = lenet_spec();
        let b1 = compress(&spec, &k1(), &DseConfig::default()).unwrap();
        let b2 = compress(&spec, &k1(), &DseConfig::default()).unwrap();
        assert_eq!(b1, b2);
        // 784->300 and 300->100 factorize; the 10-class head stays dense
        assert_eq!(b1.tt_layers(), 2);
        assert_eq!(b1.ops.len(), 5); // Tt, Relu, Tt, Relu, Dense
        assert!(matches!(b1.ops[4], BundleOp::Dense(_)));
        assert_eq!(b1.in_dim, 784);
        assert_eq!(b1.out_dim, 10);
        // compression actually compresses
        let dense_params: usize = spec
            .shapes
            .iter()
            .map(|&(n, m)| (n * m + m) as usize)
            .sum();
        assert!(b1.param_count() < dense_params / 2);
        // report carries one entry per FC layer
        assert_eq!(b1.report.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn built_engine_matches_direct_construction_bitwise() {
        let bundle = compress(&lenet_spec(), &k1(), &DseConfig::default()).unwrap();
        let mut e1 = bundle.build_engine(&k1()).unwrap();
        let mut e2 = bundle.build_engine(&k1()).unwrap();
        let mut rng = Rng::new(9);
        for batch in [1usize, 3] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let a = e1.forward(&x).unwrap();
            let b = e2.forward(&x).unwrap();
            assert_eq!(a.dims(), &[batch, 10]);
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn build_engine_rejects_wrong_machine_and_broken_chains() {
        let bundle = compress(&lenet_spec(), &k1(), &DseConfig::default()).unwrap();
        let err = bundle.build_engine(&MachineSpec::host()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");

        let mut broken = bundle.clone();
        broken.out_dim = 11;
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
        let mut broken = bundle.clone();
        broken.in_dim = 100;
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
        let mut broken = bundle;
        broken.ops.clear();
        assert!(matches!(broken.build_engine(&k1()), Err(Error::Artifact(_))));
    }

    #[test]
    fn verify_accepts_fresh_and_rejects_tampered() {
        let cfg = DseConfig::default();
        let bundle = compress(&lenet_spec(), &k1(), &cfg).unwrap();
        let report = verify(&bundle, &k1(), &cfg).unwrap();
        assert_eq!(report.fc_layers, 3);
        assert_eq!(report.tt_layers, 2);
        assert_eq!(report.outputs_checked, 4 * 10);

        // a tampered weight is caught by the byte comparison
        let mut tampered = bundle;
        for op in &mut tampered.ops {
            if let BundleOp::Tt(t) = op {
                t.packed[0].data[0] += 1.0;
                break;
            }
        }
        assert!(matches!(verify(&tampered, &k1(), &cfg), Err(Error::Artifact(_))));
    }
}
