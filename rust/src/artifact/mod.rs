//! Compressed-model artifacts: the `.ttrv` bundle format and the
//! compress → persist → warm-start pipeline around it.
//!
//! The paper's flow is offline-by-design: DSE and TT decomposition happen
//! once, and what ships to the RISC-V target is the *compressed* model.
//! This module is that deployment boundary. `ttrv compress` runs the
//! six-stage DSE engine per FC layer, TT-SVD-decomposes the (seeded demo)
//! weights, compiles and packs the kernel chain, and persists everything a
//! server needs as one versioned, checksummed binary bundle:
//!
//! * magic + format version ([`mod@format`] documents the byte layout,
//!   versioning policy and CRC scheme);
//! * the layer ops — packed TT cores in their plan-chosen `G` layout,
//!   compiled per-step plans, dense fallbacks, biases;
//! * the selected [`crate::dse::TimedSolution`] per TT layer;
//! * the full DSE report as an embedded JSON section;
//! * optionally (format v2, `ttrv compress --tune`): per-layer
//!   measured-autotuned plans in the TUNE section ([`tune_bundle`] /
//!   [`crate::kernels::Executor::tune_chain`]) — warm-started engines
//!   then serve from *measured* plans, with outputs bitwise-identical to
//!   the analytic path (tuning only moves RB factors / thread counts);
//! * optionally (format v4, `ttrv compress --quantize`): int8-quantized
//!   TT cores in the QUANT section ([`quantize_bundle`]) — warm-started
//!   engines then serve the int8 chain (f32 accumulation, per-`m`-slice
//!   scales) with ~4x fewer resident core bytes, gated by a *measured*
//!   quantization-error budget (`--max-quant-error`);
//! * optionally (`ttrv compress --rank auto`): per-layer ranks chosen by
//!   the weight-aware accuracy sweep ([`compress_auto`] /
//!   [`crate::dse::sweep_ranks`]) under an accuracy budget, with the
//!   budget and every per-layer pick recorded as additive META keys so
//!   [`verify`] replays the same path.
//!
//! Serving then warm-starts from the file
//! ([`crate::coordinator::Server::from_artifact`] /
//! [`ModelBundle::build_engine`]): zero DSE, zero decomposition, plan
//! caches pre-seeded — cold-start scales with model size instead of
//! design-space size. `ttrv artifacts-check --verify` closes the loop:
//! container + CRC validation, then a replay that requires the
//! artifact-loaded engine to match a fresh in-process compression
//! bitwise ([`verify`]).
//!
//! Module split: [`mod@format`] (container + primitives), [`writer`]
//! (encode), [`reader`] (decode, hardened against arbitrary bytes),
//! [`bundle`] (in-memory form + compress/build/verify pipelines).

pub mod format;
pub mod bundle;
pub mod writer;
pub mod reader;
pub mod lint;

pub use bundle::{
    compress, compress_auto, quantize_bundle, tune_bundle, verify, AutoRankInfo, AutoRankLayer,
    BundleOp, CompressSpec, DenseLayerBundle, ModelBundle, QuantReport, TtLayerBundle, TuneReport,
    VerifyReport,
};
pub use format::{FORMAT_VERSION, MIN_FORMAT_VERSION};
pub use lint::{lint_bundle, verify_bundle, LintReport, LintRow, PlanSource};
pub use reader::{
    list_sections, read_bundle_bytes, read_bundle_bytes_unverified, read_bundle_file, SectionInfo,
};
pub use writer::{write_bundle, write_bundle_file};
