//! `.ttrv` bundle decoder. Built to be fed arbitrary bytes: every failure
//! path — bad magic, unsupported version, truncated file, CRC mismatch,
//! out-of-range tag, oversized length field — returns a typed
//! [`Error::Artifact`] and never panics or over-allocates (length fields
//! are validated against the actual byte budget before any allocation;
//! see [`Cursor`]). Pinned by the corruption suite in
//! `rust/tests/artifact_suite.rs`.

use std::path::Path;

use crate::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
use crate::dse::{Solution, TimedSolution};
use crate::error::{Error, Result};
use crate::kernels::{GLayout, PackedG, QuantizedG, VL};
use crate::tensor::Tensor;
use crate::ttd::cost::{EinsumDims, EinsumKind};
use crate::ttd::TtLayout;
use crate::util::json::{self, Json};

use super::bundle::{
    AutoRankInfo, AutoRankLayer, BundleOp, DenseLayerBundle, ModelBundle, TtLayerBundle,
};
use super::format::*;
use super::writer::{OP_DENSE, OP_RELU, OP_TT};

/// Cap on any single tensor dimension and on total layer widths — far
/// beyond real models, tight enough that size arithmetic cannot overflow.
const DIM_CAP: usize = u32::MAX as usize;
/// Cap on the TT configuration length `d`.
const D_CAP: usize = 64;

/// One TOC entry as validated by [`list_sections`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionInfo {
    /// Section id (`SEC_META` / `SEC_OPS` / `SEC_REPORT` / future).
    pub id: u32,
    /// Payload length in bytes.
    pub len: usize,
    /// Payload CRC-32 (already verified against the payload bytes).
    pub crc: u32,
}

/// Parse and fully validate the container: magic, version, section count,
/// TOC CRC, per-entry bounds, duplicate ids, exact payload tiling (no
/// unchecksummed gaps, overlaps, or trailing bytes) and every payload
/// CRC. Returns `(id, crc, payload)` triples in TOC order.
fn parse_container(bytes: &[u8]) -> Result<Vec<(u32, u32, &[u8])>> {
    if bytes.len() < HEADER_LEN {
        return Err(Error::artifact(format!(
            "file too short for a bundle header: {} bytes < {HEADER_LEN}",
            bytes.len()
        )));
    }
    if bytes[0..4] != MAGIC {
        return Err(Error::artifact(format!(
            "bad magic {:02x?} (expected \"TTRV\")",
            &bytes[0..4]
        )));
    }
    let le32 = |off: usize| u32::from_le_bytes(bytes[off..off + 4].try_into().expect("4 bytes"));
    let version = le32(4);
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        return Err(Error::artifact(format!(
            "unsupported format version {version} (this reader supports versions \
             {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
        )));
    }
    let count = le32(8);
    if count == 0 || count > MAX_SECTIONS {
        return Err(Error::artifact(format!(
            "section count {count} out of range 1..={MAX_SECTIONS}"
        )));
    }
    let toc_len = count as usize * TOC_ENTRY_LEN;
    let toc_end = HEADER_LEN + toc_len;
    if toc_end > bytes.len() {
        return Err(Error::artifact(format!(
            "truncated TOC: need {toc_end} bytes, file has {}",
            bytes.len()
        )));
    }
    let toc = &bytes[HEADER_LEN..toc_end];
    let stored_toc_crc = le32(12);
    let actual_toc_crc = crc32(toc);
    if stored_toc_crc != actual_toc_crc {
        return Err(Error::artifact(format!(
            "TOC checksum mismatch: stored {stored_toc_crc:#010x}, computed {actual_toc_crc:#010x}"
        )));
    }
    let mut sections = Vec::with_capacity(count as usize);
    let mut seen = Vec::with_capacity(count as usize);
    let mut ranges = Vec::with_capacity(count as usize);
    for (i, entry) in toc.chunks_exact(TOC_ENTRY_LEN).enumerate() {
        let id = u32::from_le_bytes(entry[0..4].try_into().expect("4 bytes"));
        let crc = u32::from_le_bytes(entry[4..8].try_into().expect("4 bytes"));
        let off = u64::from_le_bytes(entry[8..16].try_into().expect("8 bytes"));
        let len = u64::from_le_bytes(entry[16..24].try_into().expect("8 bytes"));
        let end = off.checked_add(len).ok_or_else(|| {
            Error::artifact(format!("section {i} (id {id}): offset + length overflows"))
        })?;
        if off < toc_end as u64 || end > bytes.len() as u64 {
            return Err(Error::artifact(format!(
                "section {i} (id {id}): range {off}..{end} outside payload area \
                 {toc_end}..{}",
                bytes.len()
            )));
        }
        if seen.contains(&id) {
            return Err(Error::artifact(format!("duplicate section id {id}")));
        }
        seen.push(id);
        ranges.push((off, end));
        let payload = &bytes[off as usize..end as usize];
        let actual = crc32(payload);
        if actual != crc {
            return Err(Error::artifact(format!(
                "section {i} (id {id}): checksum mismatch: stored {crc:#010x}, \
                 computed {actual:#010x}"
            )));
        }
        sections.push((id, crc, payload));
    }
    // no unchecksummed bytes anywhere: the sections must tile the payload
    // area exactly — a gap, overlap, or trailing tail would carry bytes no
    // CRC covers
    ranges.sort_unstable();
    let mut cursor = toc_end as u64;
    for &(off, end) in &ranges {
        if off != cursor {
            return Err(Error::artifact(format!(
                "unchecksummed gap or overlapping sections at byte {cursor} (next section \
                 starts at {off})"
            )));
        }
        cursor = end;
    }
    if cursor != bytes.len() as u64 {
        return Err(Error::artifact(format!(
            "{} trailing bytes after the last section",
            bytes.len() as u64 - cursor
        )));
    }
    Ok(sections)
}

/// Validate the container and return its section table (ids, sizes, CRCs —
/// all checksums verified). The cheap half of `artifacts-check --verify`.
pub fn list_sections(bytes: &[u8]) -> Result<Vec<SectionInfo>> {
    Ok(parse_container(bytes)?
        .into_iter()
        .map(|(id, crc, payload)| SectionInfo { id, len: payload.len(), crc })
        .collect())
}

fn dim(c: &mut Cursor<'_>, what: &str) -> Result<u64> {
    Ok(c.usize_capped(DIM_CAP, what)? as u64)
}

fn decode_layout(c: &mut Cursor<'_>) -> Result<TtLayout> {
    let d = c.u32()? as usize;
    if d == 0 || d > D_CAP {
        return Err(c.invalid(format!("layout d = {d} out of range 1..={D_CAP}")));
    }
    let mut m_shape = Vec::with_capacity(d);
    let mut n_shape = Vec::with_capacity(d);
    let mut ranks = Vec::with_capacity(d + 1);
    for _ in 0..d {
        m_shape.push(dim(c, "layout m factor")?);
    }
    for _ in 0..d {
        n_shape.push(dim(c, "layout n factor")?);
    }
    for _ in 0..=d {
        ranks.push(dim(c, "layout rank")?);
    }
    // cap the layer totals before TtLayout computes products
    for (shape, what) in [(&m_shape, "M"), (&n_shape, "N")] {
        let mut total = 1u64;
        for &f in shape.iter() {
            total = total
                .checked_mul(f)
                .filter(|&t| t <= DIM_CAP as u64)
                .ok_or_else(|| c.invalid(format!("layout {what} total exceeds {DIM_CAP}")))?;
        }
    }
    TtLayout::new(m_shape, n_shape, ranks)
        .map_err(|e| c.invalid(format!("invalid layout: {e}")))
}

fn decode_bias(c: &mut Cursor<'_>, m_total: usize) -> Result<Option<Vec<f32>>> {
    match c.u8()? {
        0 => Ok(None),
        1 => {
            let len = c.count(4, "bias")?;
            if len != m_total {
                return Err(c.invalid(format!("bias length {len} != layer width {m_total}")));
            }
            Ok(Some(c.f32s(len)?))
        }
        t => Err(c.invalid(format!("bias flag {t} not 0/1"))),
    }
}

fn decode_plan(c: &mut Cursor<'_>) -> Result<OptimizationPlan> {
    let kind = match c.u8()? {
        0 => EinsumKind::First,
        1 => EinsumKind::Middle,
        2 => EinsumKind::Final,
        t => return Err(c.invalid(format!("einsum kind tag {t}"))),
    };
    let m = c.usize_capped(DIM_CAP, "plan m")?;
    let b = c.usize_capped(DIM_CAP, "plan b")?;
    let n = c.usize_capped(DIM_CAP, "plan n")?;
    let r = c.usize_capped(DIM_CAP, "plan r")?;
    let k = c.usize_capped(DIM_CAP, "plan k")?;
    let pack_g = match c.u8()? {
        0 => false,
        1 => true,
        t => return Err(c.invalid(format!("pack_g flag {t}"))),
    };
    let vector_loop = match c.u8()? {
        0 => VectorLoop::R,
        1 => VectorLoop::K,
        2 => VectorLoop::None,
        t => return Err(c.invalid(format!("vector loop tag {t}"))),
    };
    let vl = c.usize_capped(1024, "plan vl")?;
    let rm = c.usize_capped(65536, "rb rm")?;
    let rb = c.usize_capped(65536, "rb rb")?;
    let rr = c.usize_capped(65536, "rb rr")?;
    let rk = c.usize_capped(65536, "rb rk")?;
    let order = match c.u8()? {
        0 => LoopOrder::Mbrk,
        1 => LoopOrder::Bmrk,
        t => return Err(c.invalid(format!("loop order tag {t}"))),
    };
    let has_btl = match c.u8()? {
        0 => false,
        1 => true,
        t => return Err(c.invalid(format!("btl flag {t}"))),
    };
    let btl_raw = c.usize_capped(DIM_CAP, "tile btl")?;
    let threads = c.u32()?;
    if threads > 65536 {
        return Err(c.invalid(format!("plan threads {threads} out of range")));
    }
    let ls_estimate = c.u64()?;
    Ok(OptimizationPlan {
        dims: EinsumDims { kind, m, b, n, r, k },
        pack_g,
        vector_loop,
        vl,
        rb: RbFactors { rm, rb, rr, rk },
        tile: TilePlan { order, btl: has_btl.then_some(btl_raw) },
        threads,
        ls_estimate,
    })
}

fn decode_packed(c: &mut Cursor<'_>) -> Result<PackedG> {
    let layout = match c.u8()? {
        0 => GLayout::Canonical,
        1 => GLayout::PackedR,
        2 => GLayout::PackedK,
        t => return Err(c.invalid(format!("packed G layout tag {t}"))),
    };
    let r = c.usize_capped(DIM_CAP, "core r")?;
    let n = c.usize_capped(DIM_CAP, "core n")?;
    let m = c.usize_capped(DIM_CAP, "core m")?;
    let k = c.usize_capped(DIM_CAP, "core k")?;
    let r_pad = c.usize_capped(DIM_CAP, "core r_pad")?;
    let expected = match layout {
        GLayout::Canonical | GLayout::PackedK => {
            if r_pad != r {
                return Err(c.invalid(format!("r_pad {r_pad} != r {r} for unpadded layout")));
            }
            checked_mul(checked_mul(r, n, "core")?, checked_mul(m, k, "core")?, "core")?
        }
        GLayout::PackedR => {
            if r == 0 || r_pad != r.div_ceil(VL) * VL {
                return Err(c.invalid(format!(
                    "PackedR r_pad {r_pad} is not r {r} rounded up to a multiple of {VL}"
                )));
            }
            checked_mul(checked_mul(m, r_pad, "core")?, checked_mul(n, k, "core")?, "core")?
        }
    };
    let data_len = c.count(4, "packed core data")?;
    if data_len != expected {
        return Err(c.invalid(format!(
            "packed core holds {data_len} floats, layout requires {expected}"
        )));
    }
    let data = c.f32s(data_len)?;
    Ok(PackedG { layout, dims: (r, n, m, k), r_pad, data })
}

fn decode_ops(payload: &[u8]) -> Result<Vec<BundleOp>> {
    let mut c = Cursor::new(payload, "OPS section");
    let op_count = c.u32()? as usize;
    if op_count > c.remaining() {
        // every op costs at least its 1-byte tag
        return Err(c.invalid(format!(
            "op count {op_count} exceeds the {} remaining bytes",
            c.remaining()
        )));
    }
    let mut ops = Vec::with_capacity(op_count);
    for _ in 0..op_count {
        let op = match c.u8()? {
            OP_TT => {
                let layout = decode_layout(&mut c)?;
                // bound every chain slab size up front so engine
                // construction (`einsum_chain`) cannot overflow on huge
                // crafted interior ranks
                let mut cur = layout.n_total();
                for t in (0..layout.d()).rev() {
                    let [r_prev, n_t, m_t, r_t] = layout.core_shape(t);
                    let b_t = cur / (n_t as u64 * r_t as u64);
                    cur = (m_t as u64)
                        .checked_mul(b_t)
                        .and_then(|v| v.checked_mul(r_prev as u64))
                        .filter(|&v| v <= DIM_CAP as u64)
                        .ok_or_else(|| {
                            c.invalid(format!("TT chain slab at step {t} exceeds {DIM_CAP}"))
                        })?;
                }
                let sel_layout = decode_layout(&mut c)?;
                let rank = c.u64()?;
                let params = c.u64()?;
                let flops = c.u64()?;
                let time_s = c.f64()?;
                let speedup = c.f64()?;
                let bias = decode_bias(&mut c, layout.m_total() as usize)?;
                let steps = c.u32()? as usize;
                if steps != layout.d() {
                    return Err(c.invalid(format!(
                        "TT layer has {steps} chain steps but layout d = {}",
                        layout.d()
                    )));
                }
                let mut plans = Vec::with_capacity(steps);
                let mut packed = Vec::with_capacity(steps);
                for _ in 0..steps {
                    plans.push(decode_plan(&mut c)?);
                    packed.push(decode_packed(&mut c)?);
                }
                BundleOp::Tt(TtLayerBundle {
                    layout,
                    packed,
                    plans,
                    bias,
                    selected: TimedSolution {
                        solution: Solution { layout: sel_layout, rank, params, flops },
                        time_s,
                        speedup,
                    },
                    tuned: None, // filled by the TUNE section, when present
                    quant: None, // filled by the QUANT section, when present
                })
            }
            OP_DENSE => {
                let m = c.usize_capped(DIM_CAP, "dense m")?;
                let n = c.usize_capped(DIM_CAP, "dense n")?;
                let need = checked_mul(m, n, "dense weights")?;
                let w = Tensor::from_vec(vec![m, n], c.f32s(need)?)
                    .map_err(|e| c.invalid(format!("dense weights: {e}")))?;
                let bias = decode_bias(&mut c, m)?;
                BundleOp::Dense(DenseLayerBundle { w, bias })
            }
            OP_RELU => BundleOp::Relu,
            t => return Err(c.invalid(format!("unknown op tag {t}"))),
        };
        ops.push(op);
    }
    if !c.is_empty() {
        return Err(c.invalid(format!("{} trailing bytes after the last op", c.remaining())));
    }
    Ok(ops)
}

/// Decode the optional TUNE section into the already-decoded ops.
///
/// Validation mirrors [`crate::coordinator::TtFcEngine::from_parts`] plus
/// the tuning invariants: entries reference TT ops only, in strictly
/// increasing op order (the canonical encoding, which also rules out
/// duplicates); per layer the plan count equals the chain length, every
/// plan's dims equal the batch-1 chain step, and the tuned plan keeps the
/// analytic plan's vectorized loop / packing choice — tuning only ever
/// moves RB factors and thread counts, so a TUNE section that would change
/// the packed `G` layout is corrupt by definition.
///
/// From container format version 3 the payload carries one trailing field
/// after the entries: the length-prefixed name of the microkernel the
/// tuning host measured on (`Ok(Some(name))`; empty = unknown). The field
/// is observability metadata only — serving always re-probes the local
/// host for dispatch — and is absent (`Ok(None)`) in v2 payloads.
fn decode_tune(payload: &[u8], version: u32, ops: &mut [BundleOp]) -> Result<Option<String>> {
    let mut c = Cursor::new(payload, "TUNE section");
    let count = c.u32()? as usize;
    if count > ops.len() {
        return Err(c.invalid(format!(
            "TUNE entry count {count} exceeds the {} ops",
            ops.len()
        )));
    }
    let mut prev: Option<u32> = None;
    for _ in 0..count {
        let idx = c.u32()?;
        if prev.is_some_and(|p| idx <= p) {
            return Err(c.invalid(format!("TUNE op index {idx} not strictly increasing")));
        }
        prev = Some(idx);
        let t = match ops.get_mut(idx as usize) {
            Some(BundleOp::Tt(t)) => t,
            Some(_) => {
                return Err(c.invalid(format!("TUNE entry targets non-TT op {idx}")));
            }
            None => {
                return Err(c.invalid(format!("TUNE op index {idx} out of range")));
            }
        };
        let steps = c.u32()? as usize;
        if steps != t.layout.d() {
            return Err(c.invalid(format!(
                "TUNE entry for op {idx} has {steps} plans but layout d = {}",
                t.layout.d()
            )));
        }
        let chain = crate::ttd::cost::einsum_chain(&t.layout, 1);
        let mut tuned = Vec::with_capacity(steps);
        for (step, dims) in chain.iter().enumerate() {
            let plan = decode_plan(&mut c)?;
            if plan.dims != *dims {
                return Err(c.invalid(format!(
                    "TUNE op {idx} step {step}: plan is for {:?}, chain expects {:?}",
                    plan.dims, dims
                )));
            }
            let analytic = &t.plans[step];
            if plan.vector_loop != analytic.vector_loop || plan.pack_g != analytic.pack_g {
                return Err(c.invalid(format!(
                    "TUNE op {idx} step {step}: tuned plan changes the packed G layout \
                     (vector loop {:?} vs {:?})",
                    plan.vector_loop, analytic.vector_loop
                )));
            }
            tuned.push(plan);
        }
        t.tuned = Some(tuned);
    }
    // v3 trailing field: the tuning kernel name (bounded; UTF-8 checked)
    let tuned_kernel = if version >= 3 {
        let len = c.u32()? as usize;
        if len > 64 {
            return Err(c.invalid(format!("TUNE kernel name length {len} exceeds bound 64")));
        }
        let raw = c.take(len)?;
        let name = std::str::from_utf8(raw)
            .map_err(|_| c.invalid("TUNE kernel name is not valid UTF-8"))?;
        if name.is_empty() {
            None
        } else {
            Some(name.to_string())
        }
    } else {
        None
    };
    if !c.is_empty() {
        return Err(c.invalid(format!(
            "{} trailing bytes after the last TUNE entry",
            c.remaining()
        )));
    }
    Ok(tuned_kernel)
}

/// Decode one quantized core, cross-validating every structural field
/// against the already-decoded f32 packed core it shadows: same layout,
/// dims and padding, one scale per `m` slice, and an int8 payload of
/// exactly the packed core's element count. Quantization never changes
/// the memory layout — a QUANT entry that disagrees with its OPS core is
/// corrupt by definition.
fn decode_quant_core(c: &mut Cursor<'_>, packed: &PackedG) -> Result<QuantizedG> {
    let layout = match c.u8()? {
        0 => GLayout::Canonical,
        1 => GLayout::PackedR,
        2 => GLayout::PackedK,
        t => return Err(c.invalid(format!("quantized G layout tag {t}"))),
    };
    let r = c.usize_capped(DIM_CAP, "quant core r")?;
    let n = c.usize_capped(DIM_CAP, "quant core n")?;
    let m = c.usize_capped(DIM_CAP, "quant core m")?;
    let k = c.usize_capped(DIM_CAP, "quant core k")?;
    let r_pad = c.usize_capped(DIM_CAP, "quant core r_pad")?;
    if layout != packed.layout || (r, n, m, k) != packed.dims || r_pad != packed.r_pad {
        return Err(c.invalid(format!(
            "quantized core ({layout:?}, dims ({r}, {n}, {m}, {k}), r_pad {r_pad}) \
             does not match its packed core ({:?}, dims {:?}, r_pad {})",
            packed.layout, packed.dims, packed.r_pad
        )));
    }
    let scale_count = c.count(4, "quant scales")?;
    if scale_count != m {
        return Err(c.invalid(format!(
            "quantized core has {scale_count} scales for m = {m}"
        )));
    }
    let scales = c.f32s(scale_count)?;
    for (mi, &s) in scales.iter().enumerate() {
        if !(s.is_finite() && s > 0.0) {
            return Err(c.invalid(format!("quant scale {s} for slice {mi} is not positive")));
        }
    }
    let data_len = c.count(1, "quant core data")?;
    if data_len != packed.data.len() {
        return Err(c.invalid(format!(
            "quantized core holds {data_len} values, packed core holds {}",
            packed.data.len()
        )));
    }
    let data = c.take(data_len)?.iter().map(|&b| b as i8).collect();
    Ok(QuantizedG { layout, dims: (r, n, m, k), r_pad, scales, data })
}

/// Decode the optional QUANT section (format v4) into the already-decoded
/// ops. Same keying and ordering rules as [`decode_tune`]: entries
/// reference TT ops only, in strictly increasing op order, one quantized
/// core per chain step, each cross-validated against its OPS packed core.
fn decode_quant(payload: &[u8], ops: &mut [BundleOp]) -> Result<()> {
    let mut c = Cursor::new(payload, "QUANT section");
    let count = c.u32()? as usize;
    if count > ops.len() {
        return Err(c.invalid(format!(
            "QUANT entry count {count} exceeds the {} ops",
            ops.len()
        )));
    }
    let mut prev: Option<u32> = None;
    for _ in 0..count {
        let idx = c.u32()?;
        if prev.is_some_and(|p| idx <= p) {
            return Err(c.invalid(format!("QUANT op index {idx} not strictly increasing")));
        }
        prev = Some(idx);
        let t = match ops.get_mut(idx as usize) {
            Some(BundleOp::Tt(t)) => t,
            Some(_) => {
                return Err(c.invalid(format!("QUANT entry targets non-TT op {idx}")));
            }
            None => {
                return Err(c.invalid(format!("QUANT op index {idx} out of range")));
            }
        };
        let steps = c.u32()? as usize;
        if steps != t.packed.len() {
            return Err(c.invalid(format!(
                "QUANT entry for op {idx} has {steps} cores but the layer has {}",
                t.packed.len()
            )));
        }
        let mut cores = Vec::with_capacity(steps);
        for packed in &t.packed {
            cores.push(decode_quant_core(&mut c, packed)?);
        }
        t.quant = Some(cores);
    }
    if !c.is_empty() {
        return Err(c.invalid(format!(
            "{} trailing bytes after the last QUANT entry",
            c.remaining()
        )));
    }
    Ok(())
}

fn meta_err(msg: impl Into<String>) -> Error {
    Error::artifact(format!("META section: {}", msg.into()))
}

fn decode_meta(payload: &[u8]) -> Result<ModelBundle> {
    let text = std::str::from_utf8(payload).map_err(|_| meta_err("not valid UTF-8"))?;
    let doc = json::parse(text).map_err(|e| meta_err(format!("bad JSON: {e}")))?;
    if doc.get("format").and_then(Json::as_str) != Some("ttrv-bundle") {
        return Err(meta_err("missing format marker 'ttrv-bundle'"));
    }
    let str_field = |key: &str| {
        doc.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| meta_err(format!("missing string field '{key}'")))
    };
    let dim_field = |key: &str| {
        doc.get(key)
            .and_then(Json::as_u64)
            .filter(|&v| v <= DIM_CAP as u64)
            .ok_or_else(|| meta_err(format!("missing/invalid integer field '{key}'")))
    };
    let shapes_json = doc
        .get("shapes")
        .and_then(Json::as_arr)
        .ok_or_else(|| meta_err("missing 'shapes' array"))?;
    let mut shapes = Vec::with_capacity(shapes_json.len());
    for s in shapes_json {
        let pair = s.as_arr().ok_or_else(|| meta_err("shape entry is not a [n, m] pair"))?;
        let get = |i: usize| {
            pair.get(i)
                .and_then(Json::as_u64)
                .filter(|&v| v >= 1 && v <= DIM_CAP as u64)
                .ok_or_else(|| meta_err("shape entry is not a [n, m] pair of dims"))
        };
        if pair.len() != 2 {
            return Err(meta_err("shape entry is not a [n, m] pair"));
        }
        shapes.push((get(0)?, get(1)?));
    }
    // optional accuracy-budget record (additive keys): both keys come and
    // go together, and the per-layer list must cover every FC layer
    let auto = match (doc.get("auto_budget"), doc.get("auto_layers")) {
        (None, None) => None,
        (Some(b), Some(l)) => {
            let budget = b
                .as_f64()
                .filter(|v| v.is_finite() && *v > 0.0)
                .ok_or_else(|| meta_err("'auto_budget' is not a finite value > 0"))?;
            let entries = l
                .as_arr()
                .ok_or_else(|| meta_err("'auto_layers' is not an array"))?;
            if entries.len() != shapes.len() {
                return Err(meta_err(format!(
                    "'auto_layers' has {} entries for {} FC layers",
                    entries.len(),
                    shapes.len()
                )));
            }
            let mut layers = Vec::with_capacity(entries.len());
            for e in entries {
                layers.push(match e {
                    Json::Null => None,
                    _ => {
                        let rank = e
                            .get("rank")
                            .and_then(Json::as_u64)
                            .filter(|&r| r >= 1 && r <= DIM_CAP as u64)
                            .ok_or_else(|| {
                                meta_err("'auto_layers' entry has no valid 'rank' >= 1")
                            })?;
                        let rel_error = e
                            .get("rel_error")
                            .and_then(Json::as_f64)
                            .filter(|v| v.is_finite() && *v >= 0.0)
                            .ok_or_else(|| {
                                meta_err("'auto_layers' entry has no finite 'rel_error' >= 0")
                            })?;
                        Some(AutoRankLayer { rank, rel_error })
                    }
                });
            }
            Some(AutoRankInfo { budget, layers })
        }
        _ => {
            return Err(meta_err(
                "'auto_budget' and 'auto_layers' must be present together",
            ))
        }
    };
    Ok(ModelBundle {
        name: str_field("model")?,
        machine: str_field("machine")?,
        in_dim: dim_field("in_dim")? as usize,
        out_dim: dim_field("out_dim")? as usize,
        rank: dim_field("rank")?,
        seed: doc
            .get("seed")
            .and_then(Json::as_u64)
            .ok_or_else(|| meta_err("missing/invalid integer field 'seed'"))?,
        shapes,
        ops: Vec::new(),
        report: Json::Null,
        tuned_kernel: None,
        auto,
    })
}

/// Decode a bundle from its byte form, validating the container, every
/// checksum and every section grammar — but *not* the static-verification
/// gate. This exists for `ttrv lint`, which wants the full per-plan
/// violation report ([`crate::artifact::lint_bundle`]) instead of the
/// fail-fast first error; never build an engine from a bundle obtained
/// this way — use [`read_bundle_bytes`], which proves every plan safe.
pub fn read_bundle_bytes_unverified(bytes: &[u8]) -> Result<ModelBundle> {
    let sections = parse_container(bytes)?;
    let find = |id: u32, name: &str| {
        sections
            .iter()
            .find(|(sid, _, _)| *sid == id)
            .map(|(_, _, payload)| *payload)
            .ok_or_else(|| Error::artifact(format!("missing required section {name} (id {id})")))
    };
    let mut bundle = decode_meta(find(SEC_META, "META")?)?;
    bundle.ops = decode_ops(find(SEC_OPS, "OPS")?)?;
    let report_text = std::str::from_utf8(find(SEC_REPORT, "REPORT")?)
        .map_err(|_| Error::artifact("REPORT section: not valid UTF-8"))?;
    bundle.report = json::parse(report_text)
        .map_err(|e| Error::artifact(format!("REPORT section: bad JSON: {e}")))?;
    // Optional TUNE section: measured plans; absent -> every layer's
    // `tuned` stays None and engines run the analytic plans. The id only
    // *means* TUNE from format version 2 — in a version-1 file id 4 is an
    // unknown (third-party) section and is skipped per the versioning
    // policy, exactly as the v1 reader treated it.
    let version = u32::from_le_bytes(bytes[4..8].try_into().expect("validated header"));
    if version >= 2 {
        if let Some((_, _, payload)) = sections.iter().find(|(sid, _, _)| *sid == SEC_TUNE) {
            bundle.tuned_kernel = decode_tune(payload, version, &mut bundle.ops)?;
        }
    }
    // Optional QUANT section: int8 cores; absent -> every layer's `quant`
    // stays None and engines serve the f32 packed cores. Same versioning
    // rule as TUNE: id 5 only *means* QUANT from format version 4.
    if version >= 4 {
        if let Some((_, _, payload)) = sections.iter().find(|(sid, _, _)| *sid == SEC_QUANT) {
            decode_quant(payload, &mut bundle.ops)?;
        }
    }
    Ok(bundle)
}

/// Decode a bundle from its byte form, validating the container, every
/// checksum, every section grammar — and then the static-verification
/// chokepoint: every decoded plan × core pair (analytic OPS, measured
/// TUNE, int8 QUANT) must pass the strict tier of
/// [`crate::compiler::verify`] before the bundle reaches any executor.
/// The per-section grammars bound *parsing*; this proves *execution*
/// safety (geometry, pad lanes, register budget) for externally-sourced
/// bytes whose CRCs an attacker controls.
pub fn read_bundle_bytes(bytes: &[u8]) -> Result<ModelBundle> {
    let bundle = read_bundle_bytes_unverified(bytes)?;
    super::lint::verify_bundle(&bundle)?;
    Ok(bundle)
}

/// Read and decode a bundle file.
pub fn read_bundle_file(path: impl AsRef<Path>) -> Result<ModelBundle> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| {
        Error::artifact(format!("cannot read bundle {}: {e}", path.display()))
    })?;
    read_bundle_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DseConfig;
    use crate::machine::MachineSpec;

    fn sample_bundle() -> ModelBundle {
        let spec = super::super::CompressSpec::from_zoo("lenet300", 8, 5).unwrap();
        super::super::compress(&spec, &MachineSpec::spacemit_k1(), &DseConfig::default()).unwrap()
    }

    #[test]
    fn roundtrip_restores_every_field() {
        let bundle = sample_bundle();
        let bytes = super::super::write_bundle(&bundle);
        let back = read_bundle_bytes(&bytes).unwrap();
        assert_eq!(back, bundle);
        // canonical encoding: re-encoding the decoded bundle is stable
        assert_eq!(super::super::write_bundle(&back), bytes);
    }

    #[test]
    fn section_listing_reports_all_three() {
        let bytes = super::super::write_bundle(&sample_bundle());
        let secs = list_sections(&bytes).unwrap();
        assert_eq!(
            secs.iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![SEC_META, SEC_OPS, SEC_REPORT]
        );
        assert!(secs.iter().all(|s| s.len > 0));
    }

    #[test]
    fn unknown_extra_section_is_skipped() {
        // additive sections must not require a version bump: append a
        // fourth section with an unknown id and re-point the TOC
        let bundle = sample_bundle();
        let mut bytes = Vec::new();
        {
            // rebuild the container by hand with an extra section
            let sections = parse_container(&super::super::write_bundle(&bundle))
                .unwrap()
                .iter()
                .map(|(id, _, p)| (*id, p.to_vec()))
                .chain(std::iter::once((99u32, b"future".to_vec())))
                .collect::<Vec<_>>();
            let mut toc = Vec::new();
            let mut offset = (HEADER_LEN + sections.len() * TOC_ENTRY_LEN) as u64;
            for (id, payload) in &sections {
                put_u32(&mut toc, *id);
                put_u32(&mut toc, crc32(payload));
                put_u64(&mut toc, offset);
                put_u64(&mut toc, payload.len() as u64);
                offset += payload.len() as u64;
            }
            bytes.extend_from_slice(&MAGIC);
            put_u32(&mut bytes, FORMAT_VERSION);
            put_u32(&mut bytes, sections.len() as u32);
            put_u32(&mut bytes, crc32(&toc));
            bytes.extend_from_slice(&toc);
            for (_, payload) in &sections {
                bytes.extend_from_slice(payload);
            }
        }
        let back = read_bundle_bytes(&bytes).unwrap();
        assert_eq!(back, bundle);
    }

    #[test]
    fn decoded_plans_must_pass_static_verification() {
        // a plan the per-field grammar caps accept (threads <= 65536) but
        // the strict verify tier rejects — re-encoded with valid CRCs, so
        // only the chokepoint in `read_bundle_bytes` can catch it
        let mut bundle = sample_bundle();
        let BundleOp::Tt(t) = &mut bundle.ops[0] else { panic!("op 0 is TT") };
        t.plans[0].threads = 0;
        let bytes = super::super::write_bundle(&bundle);
        assert!(read_bundle_bytes_unverified(&bytes).is_ok());
        let err = read_bundle_bytes(&bytes).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        assert!(err.to_string().contains("threads-positive"), "{err}");
    }

    #[test]
    fn missing_required_section_is_typed() {
        let bundle = sample_bundle();
        let full = super::super::write_bundle(&bundle);
        // rebuild with only META
        let sections = parse_container(&full).unwrap();
        let meta = sections[0].2.to_vec();
        let mut toc = Vec::new();
        put_u32(&mut toc, SEC_META);
        put_u32(&mut toc, crc32(&meta));
        put_u64(&mut toc, (HEADER_LEN + TOC_ENTRY_LEN) as u64);
        put_u64(&mut toc, meta.len() as u64);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        put_u32(&mut bytes, FORMAT_VERSION);
        put_u32(&mut bytes, 1);
        put_u32(&mut bytes, crc32(&toc));
        bytes.extend_from_slice(&toc);
        bytes.extend_from_slice(&meta);
        let err = read_bundle_bytes(&bytes).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        assert!(err.to_string().contains("OPS"));
    }
}
