//! Bundle-wide static verification — the artifact side of `ttrv lint`.
//!
//! A `.ttrv` bundle injects externally-sourced plans (OPS, TUNE) and cores
//! (OPS, QUANT) straight into the serving executor, so every plan × core
//! pair it carries is run through the strict tier of
//! [`crate::compiler::verify`] — the machine register budget (resolved
//! from the bundle's META `machine` name via
//! [`MachineSpec::by_name`]; unknown machines skip only that check) plus
//! the packed-geometry and pad-lane proofs against the concrete stored
//! cores.
//!
//! Two consumers share the walk:
//!
//! * [`lint_bundle`] collects *every* violation into a [`LintReport`] with
//!   one [`LintRow`] per plan × core pair — the `ttrv lint` subcommand
//!   renders it as text or as the `ttrv-lint-report` v1 JSON schema.
//! * [`verify_bundle`] is the decode chokepoint:
//!   [`crate::artifact::read_bundle_bytes`] calls it on every decoded
//!   bundle and refuses to return one that fails, as a typed
//!   [`Error::Artifact`] naming the first offending layer/step/invariant.
//!
//! [`Error::Artifact`]: crate::error::Error::Artifact

use crate::artifact::bundle::{BundleOp, ModelBundle};
use crate::compiler::verify::{self, Violation};
use crate::compiler::OptimizationPlan;
use crate::error::{Error, Result};
use crate::kernels::{GLayout, PackedG, QuantizedG};
use crate::machine::MachineSpec;
use crate::util::json::Json;

/// Which plan list of a TT layer a lint row came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// The analytic OPS plan the compiler selected.
    Selected,
    /// A measured-autotuned TUNE plan.
    Tuned,
}

impl PlanSource {
    /// Stable lowercase name (the JSON report's `source` enum).
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanSource::Selected => "selected",
            PlanSource::Tuned => "tuned",
        }
    }
}

/// One plan × core pair's verification outcome.
#[derive(Debug, Clone)]
pub struct LintRow {
    /// Index of the op in [`ModelBundle::ops`].
    pub layer: usize,
    /// Chain step within the layer (processing order, t = d-1 .. 0).
    pub step: usize,
    /// Which plan list the plan came from.
    pub source: PlanSource,
    /// The plan that was checked.
    pub plan: OptimizationPlan,
    /// The stored core's layout.
    pub layout: GLayout,
    /// The plan's vector-register demand (paper Eq. 19).
    pub registers: usize,
    /// Whether an int8 QUANT shadow core was cross-checked too.
    pub quant: bool,
    /// Every violated invariant (empty = this pair proved safe).
    pub violations: Vec<Violation>,
}

/// The full bundle verification result: one row per plan × core pair.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// Model display name from the bundle.
    pub model: String,
    /// The META `machine` name the plans were compiled for.
    pub machine: String,
    /// Whether [`MachineSpec::by_name`] knows that machine — when `false`
    /// the register-budget check was skipped (every other check still ran).
    pub machine_known: bool,
    /// One row per checked plan × core pair, bundle order.
    pub rows: Vec<LintRow>,
}

impl LintReport {
    /// How many plan × core pairs were checked.
    pub fn plans_checked(&self) -> usize {
        self.rows.len()
    }

    /// Total violations across every row.
    pub fn violations(&self) -> usize {
        self.rows.iter().map(|r| r.violations.len()).sum()
    }

    /// `true` when every pair proved safe.
    pub fn clean(&self) -> bool {
        self.rows.iter().all(|r| r.violations.is_empty())
    }

    /// The `ttrv-lint-report` v1 JSON document (`source` names where the
    /// bundle came from: an artifact path or a `zoo:<name>` tag).
    pub fn to_json(&self, source: &str) -> Json {
        let results: Vec<Json> = self.rows.iter().map(row_json).collect();
        Json::obj(vec![
            ("schema", Json::from("ttrv-lint-report")),
            ("schema_version", Json::from(1usize)),
            ("source", Json::from(source)),
            ("model", Json::from(self.model.as_str())),
            ("machine", Json::from(self.machine.as_str())),
            ("machine_known", Json::from(self.machine_known)),
            ("plans_checked", Json::from(self.plans_checked())),
            ("violations", Json::from(self.violations())),
            ("clean", Json::from(self.clean())),
            ("results", Json::Arr(results)),
        ])
    }
}

fn row_json(r: &LintRow) -> Json {
    let d = &r.plan.dims;
    Json::obj(vec![
        ("layer", Json::from(r.layer)),
        ("step", Json::from(r.step)),
        ("source", Json::from(r.source.as_str())),
        ("kind", Json::from(format!("{:?}", d.kind).as_str())),
        ("m", Json::from(d.m)),
        ("b", Json::from(d.b)),
        ("n", Json::from(d.n)),
        ("r", Json::from(d.r)),
        ("k", Json::from(d.k)),
        ("layout", Json::from(format!("{:?}", r.layout).as_str())),
        ("vector_loop", Json::from(format!("{:?}", r.plan.vector_loop).as_str())),
        ("vl", Json::from(r.plan.vl)),
        ("rm", Json::from(r.plan.rb.rm)),
        ("rb", Json::from(r.plan.rb.rb)),
        ("rr", Json::from(r.plan.rb.rr)),
        ("rk", Json::from(r.plan.rb.rk)),
        ("registers", Json::from(r.registers)),
        ("threads", Json::from(r.plan.threads)),
        ("quant", Json::from(r.quant)),
        ("status", Json::from(if r.violations.is_empty() { "ok" } else { "violated" })),
        (
            "violations",
            Json::Arr(
                r.violations
                    .iter()
                    .map(|v| {
                        Json::obj(vec![
                            ("invariant", Json::from(v.invariant)),
                            ("detail", Json::from(v.detail.as_str())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Strict-tier checks for one plan against its stored cores.
fn check_pair(
    layer: usize,
    step: usize,
    source: PlanSource,
    plan: &OptimizationPlan,
    packed: &PackedG,
    quant: Option<&QuantizedG>,
    machine: Option<&MachineSpec>,
) -> LintRow {
    let mut violations = match machine {
        Some(m) => verify::check_plan_for(plan, m),
        None => verify::check_plan(plan),
    };
    violations.extend(verify::check_packed(plan, packed));
    if let Some(q) = quant {
        violations.extend(verify::check_quant(plan, q));
    }
    LintRow {
        layer,
        step,
        source,
        plan: *plan,
        layout: packed.layout,
        registers: plan.rb.registers(),
        quant: quant.is_some(),
        violations,
    }
}

/// Run the full strict-tier analysis over every plan × core pair in the
/// bundle: analytic OPS plans and (when present) measured TUNE plans, each
/// against the stored f32 core and (when present) its int8 QUANT shadow.
/// Collects every violation; [`verify_bundle`] is the fail-fast twin.
pub fn lint_bundle(bundle: &ModelBundle) -> LintReport {
    let machine = MachineSpec::by_name(&bundle.machine);
    let mut rows = Vec::new();
    for (layer, op) in bundle.ops.iter().enumerate() {
        let BundleOp::Tt(t) = op else { continue };
        let quant_at = |step: usize| t.quant.as_ref().and_then(|qs| qs.get(step));
        for (step, (plan, packed)) in t.plans.iter().zip(&t.packed).enumerate() {
            rows.push(check_pair(
                layer,
                step,
                PlanSource::Selected,
                plan,
                packed,
                quant_at(step),
                machine.as_ref(),
            ));
        }
        if let Some(tuned) = &t.tuned {
            for (step, (plan, packed)) in tuned.iter().zip(&t.packed).enumerate() {
                rows.push(check_pair(
                    layer,
                    step,
                    PlanSource::Tuned,
                    plan,
                    packed,
                    quant_at(step),
                    machine.as_ref(),
                ));
            }
        }
    }
    LintReport {
        model: bundle.name.clone(),
        machine: bundle.machine.clone(),
        machine_known: machine.is_some(),
        rows,
    }
}

/// The artifact-decode chokepoint: [`lint_bundle`] as a typed
/// [`Error::Artifact`] naming the first offending layer/step/invariant
/// (and the total count, so a multi-fault bundle is obvious).
/// [`crate::artifact::read_bundle_bytes`] calls this on every decode — a
/// bundle that fails never reaches an executor.
pub fn verify_bundle(bundle: &ModelBundle) -> Result<()> {
    let report = lint_bundle(bundle);
    if report.clean() {
        return Ok(());
    }
    let row = report
        .rows
        .iter()
        .find(|r| !r.violations.is_empty())
        .expect("non-clean report has a violating row");
    let msgs: Vec<String> = row.violations.iter().map(|v| v.to_string()).collect();
    Err(Error::artifact(format!(
        "bundle '{}' fails static verification ({} violation(s) across {} plan(s)); \
         first: layer {} step {} ({} plan): {}",
        report.model,
        report.violations(),
        report.plans_checked(),
        row.layer,
        row.step,
        row.source.as_str(),
        msgs.join("; ")
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{compress, CompressSpec};
    use crate::config::DseConfig;

    fn sample() -> ModelBundle {
        let spec = CompressSpec::from_zoo("lenet300", 8, 5).unwrap();
        compress(&spec, &MachineSpec::spacemit_k1(), &DseConfig::default()).unwrap()
    }

    #[test]
    fn fresh_compression_lints_clean() {
        let b = sample();
        let report = lint_bundle(&b);
        assert!(report.plans_checked() > 0);
        assert!(report.machine_known);
        assert!(report.clean(), "{:?}", report.rows.iter().flat_map(|r| &r.violations).collect::<Vec<_>>());
        assert!(verify_bundle(&b).is_ok());
    }

    #[test]
    fn corrupted_plan_is_named_by_layer_step_and_invariant() {
        let mut b = sample();
        let BundleOp::Tt(t) = &mut b.ops[0] else { panic!("op 0 is TT") };
        t.plans[1].threads = 0;
        let report = lint_bundle(&b);
        assert!(!report.clean());
        let bad: Vec<_> = report.rows.iter().filter(|r| !r.violations.is_empty()).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!((bad[0].layer, bad[0].step), (0, 1));
        assert_eq!(bad[0].violations[0].invariant, "threads-positive");
        let err = verify_bundle(&b).unwrap_err().to_string();
        assert!(err.contains("layer 0 step 1"), "{err}");
        assert!(err.contains("threads-positive"), "{err}");
    }

    #[test]
    fn unknown_machine_skips_only_the_budget_check() {
        let mut b = sample();
        b.machine = "riscv-unknown".to_string();
        let report = lint_bundle(&b);
        assert!(!report.machine_known);
        assert!(report.clean()); // everything else still ran and passed
        // an over-budget RB now passes (no machine to budget against)...
        let BundleOp::Tt(t) = &mut b.ops[0] else { panic!("op 0 is TT") };
        t.plans[0].rb = crate::compiler::RbFactors { rm: 8, rb: 8, rr: 1, rk: 1 };
        assert!(lint_bundle(&b).clean());
        // ...but the same bundle on a known machine is rejected by budget
        b.machine = "SpacemiT-K1".to_string();
        let report = lint_bundle(&b);
        let bad: Vec<_> = report.rows.iter().filter(|r| !r.violations.is_empty()).collect();
        assert_eq!(bad[0].violations[0].invariant, "rb-register-budget");
    }

    #[test]
    fn report_json_matches_schema_v1() {
        let report = lint_bundle(&sample());
        let doc = report.to_json("zoo:lenet300");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("ttrv-lint-report"));
        assert_eq!(doc.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("clean").and_then(Json::as_bool), Some(true));
        assert_eq!(
            doc.get("plans_checked").and_then(Json::as_usize),
            Some(report.plans_checked())
        );
        let results = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), report.plans_checked());
        for r in results {
            assert_eq!(r.get("status").and_then(Json::as_str), Some("ok"));
            assert_eq!(r.get("source").and_then(Json::as_str), Some("selected"));
            assert!(r.get("registers").and_then(Json::as_usize).unwrap() >= 3);
        }
    }
}
