//! `.ttrv` bundle encoder. The encoding is **canonical**: a given
//! [`ModelBundle`] always serializes to the same bytes (sections in fixed
//! order, sorted JSON keys, little-endian scalars), which is what lets
//! [`super::bundle::verify`] compare a decoded bundle against a fresh
//! compression byte-for-byte.

use std::path::Path;

use crate::compiler::plan::{LoopOrder, OptimizationPlan, VectorLoop};
use crate::error::Result;
use crate::kernels::{GLayout, PackedG, QuantizedG};
use crate::ttd::cost::EinsumKind;
use crate::ttd::TtLayout;
use crate::util::json::{self, Json};

use super::bundle::{BundleOp, ModelBundle};
use super::format::*;

/// Op tags in the OPS section.
pub(super) const OP_TT: u8 = 0;
/// Dense FC op tag.
pub(super) const OP_DENSE: u8 = 1;
/// ReLU op tag.
pub(super) const OP_RELU: u8 = 2;

fn encode_layout(out: &mut Vec<u8>, layout: &TtLayout) {
    put_u32(out, layout.d() as u32);
    for &v in layout.m_shape() {
        put_u64(out, v);
    }
    for &v in layout.n_shape() {
        put_u64(out, v);
    }
    for &v in layout.ranks() {
        put_u64(out, v);
    }
}

fn encode_bias(out: &mut Vec<u8>, bias: &Option<Vec<f32>>) {
    match bias {
        None => put_u8(out, 0),
        Some(b) => {
            put_u8(out, 1);
            put_u64(out, b.len() as u64);
            put_f32s(out, b);
        }
    }
}

pub(super) fn encode_plan(out: &mut Vec<u8>, plan: &OptimizationPlan) {
    let d = &plan.dims;
    put_u8(out, match d.kind {
        EinsumKind::First => 0,
        EinsumKind::Middle => 1,
        EinsumKind::Final => 2,
    });
    for v in [d.m, d.b, d.n, d.r, d.k] {
        put_u64(out, v as u64);
    }
    put_u8(out, plan.pack_g as u8);
    put_u8(out, match plan.vector_loop {
        VectorLoop::R => 0,
        VectorLoop::K => 1,
        VectorLoop::None => 2,
    });
    put_u64(out, plan.vl as u64);
    for v in [plan.rb.rm, plan.rb.rb, plan.rb.rr, plan.rb.rk] {
        put_u64(out, v as u64);
    }
    put_u8(out, match plan.tile.order {
        LoopOrder::Mbrk => 0,
        LoopOrder::Bmrk => 1,
    });
    put_u8(out, plan.tile.btl.is_some() as u8);
    put_u64(out, plan.tile.btl.unwrap_or(0) as u64);
    put_u32(out, plan.threads);
    put_u64(out, plan.ls_estimate);
}

pub(super) fn encode_packed(out: &mut Vec<u8>, g: &PackedG) {
    put_u8(out, match g.layout {
        GLayout::Canonical => 0,
        GLayout::PackedR => 1,
        GLayout::PackedK => 2,
    });
    let (r, n, m, k) = g.dims;
    for v in [r, n, m, k, g.r_pad] {
        put_u64(out, v as u64);
    }
    put_u64(out, g.data.len() as u64);
    put_f32s(out, &g.data);
}

pub(super) fn encode_quant_core(out: &mut Vec<u8>, q: &QuantizedG) {
    put_u8(out, match q.layout {
        GLayout::Canonical => 0,
        GLayout::PackedR => 1,
        GLayout::PackedK => 2,
    });
    let (r, n, m, k) = q.dims;
    for v in [r, n, m, k, q.r_pad] {
        put_u64(out, v as u64);
    }
    put_u64(out, q.scales.len() as u64);
    put_f32s(out, &q.scales);
    put_u64(out, q.data.len() as u64);
    // i8 payload stored as raw two's-complement bytes
    out.reserve(q.data.len());
    for &v in &q.data {
        out.push(v as u8);
    }
}

fn encode_ops(bundle: &ModelBundle) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, bundle.ops.len() as u32);
    for op in &bundle.ops {
        match op {
            BundleOp::Tt(t) => {
                // a hand-built bundle with mismatched lengths must fail
                // here, loudly, not decode-time with a confusing
                // "truncated" error
                assert_eq!(
                    t.plans.len(),
                    t.packed.len(),
                    "TtLayerBundle has {} plans but {} packed cores",
                    t.plans.len(),
                    t.packed.len()
                );
                put_u8(&mut out, OP_TT);
                encode_layout(&mut out, &t.layout);
                encode_layout(&mut out, t.selected.layout());
                put_u64(&mut out, t.selected.solution.rank);
                put_u64(&mut out, t.selected.solution.params);
                put_u64(&mut out, t.selected.solution.flops);
                put_f64(&mut out, t.selected.time_s);
                put_f64(&mut out, t.selected.speedup);
                encode_bias(&mut out, &t.bias);
                put_u32(&mut out, t.plans.len() as u32);
                for (plan, packed) in t.plans.iter().zip(&t.packed) {
                    encode_plan(&mut out, plan);
                    encode_packed(&mut out, packed);
                }
            }
            BundleOp::Dense(dl) => {
                put_u8(&mut out, OP_DENSE);
                let dims = dl.w.dims();
                put_u64(&mut out, dims[0] as u64);
                put_u64(&mut out, dims[1] as u64);
                put_f32s(&mut out, dl.w.data());
                encode_bias(&mut out, &dl.bias);
            }
            BundleOp::Relu => put_u8(&mut out, OP_RELU),
        }
    }
    out
}

/// The optional TUNE section: measured plans per TT layer, keyed by op
/// index, followed (format v3) by the name of the microkernel the tuning
/// host measured the winners on (length-prefixed UTF-8; empty = unknown).
/// `None` when no layer carries tuned plans — the section is then
/// omitted entirely, so an untuned bundle's encoding is identical in
/// shape to a format-v1 bundle (plus the version field).
fn encode_tune(bundle: &ModelBundle) -> Option<Vec<u8>> {
    let entries: Vec<(u32, &[OptimizationPlan])> = bundle
        .ops
        .iter()
        .enumerate()
        .filter_map(|(i, op)| match op {
            BundleOp::Tt(t) => t.tuned.as_ref().map(|plans| {
                // same loud construction-time check as plans/packed: a
                // hand-built mismatch must not surface as a decode error
                assert_eq!(
                    plans.len(),
                    t.plans.len(),
                    "TtLayerBundle has {} tuned plans but {} chain steps",
                    plans.len(),
                    t.plans.len()
                );
                (i as u32, plans.as_slice())
            }),
            _ => None,
        })
        .collect();
    if entries.is_empty() {
        return None;
    }
    let mut out = Vec::new();
    put_u32(&mut out, entries.len() as u32);
    for (idx, plans) in entries {
        put_u32(&mut out, idx);
        put_u32(&mut out, plans.len() as u32);
        for plan in plans {
            encode_plan(&mut out, plan);
        }
    }
    // v3 trailing field, deliberately *after* all entries so the absolute
    // entry offsets of v2 payloads are unchanged: the tuning kernel name
    // (observability only — load-time dispatch always re-probes the host)
    let name = bundle.tuned_kernel.as_deref().unwrap_or("");
    put_u32(&mut out, name.len() as u32);
    out.extend_from_slice(name.as_bytes());
    Some(out)
}

/// The optional QUANT section (format v4): int8-quantized cores per TT
/// layer, keyed by op index exactly like TUNE. `None` when no layer
/// carries quantized cores — the section is then omitted entirely, so an
/// unquantized bundle's encoding is unchanged from format v3.
fn encode_quant(bundle: &ModelBundle) -> Option<Vec<u8>> {
    let entries: Vec<(u32, &[QuantizedG])> = bundle
        .ops
        .iter()
        .enumerate()
        .filter_map(|(i, op)| match op {
            BundleOp::Tt(t) => t.quant.as_ref().map(|cores| {
                // same loud construction-time check as plans/packed/tuned
                assert_eq!(
                    cores.len(),
                    t.packed.len(),
                    "TtLayerBundle has {} quantized cores but {} packed cores",
                    cores.len(),
                    t.packed.len()
                );
                (i as u32, cores.as_slice())
            }),
            _ => None,
        })
        .collect();
    if entries.is_empty() {
        return None;
    }
    let mut out = Vec::new();
    put_u32(&mut out, entries.len() as u32);
    for (idx, cores) in entries {
        put_u32(&mut out, idx);
        put_u32(&mut out, cores.len() as u32);
        for q in cores {
            encode_quant_core(&mut out, q);
        }
    }
    Some(out)
}

fn encode_meta(bundle: &ModelBundle) -> Vec<u8> {
    let shapes = Json::Arr(
        bundle
            .shapes
            .iter()
            .map(|&(n, m)| Json::Arr(vec![Json::from(n as usize), Json::from(m as usize)]))
            .collect(),
    );
    let mut fields = vec![
        ("format", Json::from("ttrv-bundle")),
        ("model", Json::from(bundle.name.as_str())),
        ("machine", Json::from(bundle.machine.as_str())),
        ("in_dim", Json::from(bundle.in_dim)),
        ("out_dim", Json::from(bundle.out_dim)),
        ("rank", Json::from(bundle.rank as usize)),
        ("seed", Json::from(bundle.seed as usize)),
        ("shapes", shapes),
    ];
    // accuracy-budget compression record — additive keys, so fixed-rank
    // bundles stay byte-identical to earlier format-v4 writers
    if let Some(auto) = &bundle.auto {
        fields.push(("auto_budget", Json::from(auto.budget)));
        fields.push((
            "auto_layers",
            Json::Arr(
                auto.layers
                    .iter()
                    .map(|l| match l {
                        Some(a) => Json::obj(vec![
                            ("rank", Json::from(a.rank as usize)),
                            ("rel_error", Json::from(a.rel_error)),
                        ]),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ));
    }
    json::to_string(&Json::obj(fields)).into_bytes()
}

/// Serialize a bundle to its canonical byte form.
///
/// # Panics
///
/// If a hand-built `TtLayerBundle` has differing
/// `plans`/`packed`/`tuned`/`quant` lengths (invariants every constructor
/// in this crate maintains).
pub fn write_bundle(bundle: &ModelBundle) -> Vec<u8> {
    let mut sections: Vec<(u32, Vec<u8>)> = vec![
        (SEC_META, encode_meta(bundle)),
        (SEC_OPS, encode_ops(bundle)),
        (SEC_REPORT, json::to_string(&bundle.report).into_bytes()),
    ];
    if let Some(tune) = encode_tune(bundle) {
        sections.push((SEC_TUNE, tune));
    }
    if let Some(quant) = encode_quant(bundle) {
        sections.push((SEC_QUANT, quant));
    }
    let mut toc = Vec::with_capacity(sections.len() * TOC_ENTRY_LEN);
    let mut offset = (HEADER_LEN + sections.len() * TOC_ENTRY_LEN) as u64;
    for (id, payload) in &sections {
        put_u32(&mut toc, *id);
        put_u32(&mut toc, crc32(payload));
        put_u64(&mut toc, offset);
        put_u64(&mut toc, payload.len() as u64);
        offset += payload.len() as u64;
    }
    let mut out = Vec::with_capacity(offset as usize);
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u32(&mut out, sections.len() as u32);
    put_u32(&mut out, crc32(&toc));
    out.extend_from_slice(&toc);
    for (_, payload) in &sections {
        out.extend_from_slice(payload);
    }
    out
}

/// Serialize a bundle and write it to `path`.
pub fn write_bundle_file(path: impl AsRef<Path>, bundle: &ModelBundle) -> Result<()> {
    Ok(std::fs::write(path, write_bundle(bundle))?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Round-trip coverage lives in `reader::tests` and
    // `rust/tests/artifact_suite.rs`; here we pin container-level facts.

    fn tiny_bundle() -> ModelBundle {
        ModelBundle {
            name: "tiny".into(),
            machine: "SpacemiT-K1".into(),
            in_dim: 4,
            out_dim: 2,
            rank: 8,
            seed: 1,
            shapes: vec![(4, 2)],
            ops: vec![BundleOp::Dense(super::super::bundle::DenseLayerBundle {
                w: crate::tensor::Tensor::zeros(vec![2, 4]),
                bias: None,
            })],
            report: Json::Arr(vec![]),
            tuned_kernel: None,
            auto: None,
        }
    }

    #[test]
    fn header_layout_is_stable() {
        let bytes = write_bundle(&tiny_bundle());
        assert_eq!(&bytes[0..4], b"TTRV");
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), FORMAT_VERSION);
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 3);
        let toc = &bytes[HEADER_LEN..HEADER_LEN + 3 * TOC_ENTRY_LEN];
        assert_eq!(u32::from_le_bytes(bytes[12..16].try_into().unwrap()), crc32(toc));
        // first TOC entry is META at the first post-TOC byte
        assert_eq!(u32::from_le_bytes(toc[0..4].try_into().unwrap()), SEC_META);
        let meta_off = u64::from_le_bytes(toc[8..16].try_into().unwrap()) as usize;
        assert_eq!(meta_off, HEADER_LEN + 3 * TOC_ENTRY_LEN);
        assert_eq!(bytes[meta_off], b'{');
    }

    #[test]
    fn encoding_is_deterministic() {
        let b = tiny_bundle();
        assert_eq!(write_bundle(&b), write_bundle(&b));
    }
}
