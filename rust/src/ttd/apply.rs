//! Reference TT forward pass (paper Listing 1) and dense reconstruction.
//!
//! These are the *correctness* paths: the serving engine uses the optimized
//! kernel pipeline in [`crate::kernels`], which is tested against this
//! module. Mirrors `python/compile/kernels/ref.py` (`tt_forward_ref`,
//! `tt_reconstruct`).

use crate::error::{Error, Result};
use crate::tensor::einsum::tt_einsum_ref;
use crate::tensor::Tensor;

/// Forward pass `Y = X W^T + b` through the einsum chain.
///
/// `x` is `(B, N)`; cores are T3F `(r_{t-1}, n_t, m_t, r_t)`; result is
/// `(B, M)`.
pub fn tt_forward(cores: &[Tensor], x: &Tensor, bias: Option<&[f32]>) -> Result<Tensor> {
    let dx = x.dims();
    if dx.len() != 2 {
        return Err(Error::shape("tt_forward expects (B, N) input"));
    }
    let batch = dx[0];
    let n_total: usize = cores.iter().map(|c| c.dims()[1]).product();
    let m_total: usize = cores.iter().map(|c| c.dims()[2]).product();
    if dx[1] != n_total {
        return Err(Error::shape(format!(
            "input width {} != prod(n_t) {}",
            dx[1], n_total
        )));
    }
    let mut cur = x.clone().reshape(vec![batch * n_total])?;
    for core in cores.iter().rev() {
        let [_, n_t, _, r_t] = [
            core.dims()[0],
            core.dims()[1],
            core.dims()[2],
            core.dims()[3],
        ];
        let size = cur.numel();
        if size % (n_t * r_t) != 0 {
            return Err(Error::shape(format!(
                "chain size {size} not divisible by n_t*r_t = {}",
                n_t * r_t
            )));
        }
        let b_t = size / (n_t * r_t);
        let slab = cur.reshape(vec![b_t, n_t, r_t])?;
        let out = tt_einsum_ref(core, &slab)?; // (m_t, b_t, r_prev)
        let total = out.numel();
        cur = out.reshape(vec![total])?;
    }
    // final layout: (i_1..i_d, batch) = (M, B) row-major -> transpose
    let y = cur.reshape(vec![m_total, batch])?.transpose(&[1, 0])?;
    match bias {
        None => Ok(y),
        Some(b) => {
            if b.len() != m_total {
                return Err(Error::shape(format!(
                    "bias len {} != M {m_total}",
                    b.len()
                )));
            }
            let mut y = y;
            for row in 0..batch {
                let slice = &mut y.data_mut()[row * m_total..(row + 1) * m_total];
                for (v, &bv) in slice.iter_mut().zip(b) {
                    *v += bv;
                }
            }
            Ok(y)
        }
    }
}

/// Densify cores back to `W (M, N)` (row-major multi-index convention).
pub fn reconstruct(cores: &[Tensor]) -> Result<Tensor> {
    if cores.is_empty() {
        return Err(Error::shape("reconstruct of empty core list"));
    }
    // acc carries (M_t, N_t, r_t); start with (1, 1, 1) identity
    let mut acc = Tensor::from_vec(vec![1, 1, 1], vec![1.0])?;
    for core in cores {
        let [r_prev, n_t, m_t, r_t] = [
            core.dims()[0],
            core.dims()[1],
            core.dims()[2],
            core.dims()[3],
        ];
        let (mp, np_, rp) = (acc.dims()[0], acc.dims()[1], acc.dims()[2]);
        if rp != r_prev {
            return Err(Error::shape(format!(
                "core rank mismatch: acc r {rp} vs core r_prev {r_prev}"
            )));
        }
        // next[Pm, m, Qn, n, r] = sum_rp acc[Pm, Qn, rp] * core[rp, n, m, r]
        let mut next = Tensor::zeros(vec![mp, m_t, np_, n_t, r_t]);
        {
            let ad = acc.data();
            let cd = core.data();
            let nd = next.data_mut();
            for pm in 0..mp {
                for mi in 0..m_t {
                    for qn in 0..np_ {
                        for ni in 0..n_t {
                            let out_base = (((pm * m_t + mi) * np_ + qn) * n_t + ni) * r_t;
                            for ri in 0..r_t {
                                let mut s = 0.0f32;
                                for rpi in 0..rp {
                                    let a = ad[(pm * np_ + qn) * rp + rpi];
                                    let c = cd[((rpi * n_t + ni) * m_t + mi) * r_t + ri];
                                    s += a * c;
                                }
                                nd[out_base + ri] = s;
                            }
                        }
                    }
                }
            }
        }
        acc = next.reshape(vec![mp * m_t, np_ * n_t, r_t])?;
    }
    let (m, n, r) = (acc.dims()[0], acc.dims()[1], acc.dims()[2]);
    if r != 1 {
        return Err(Error::shape(format!("trailing rank {r} != 1")));
    }
    acc.reshape(vec![m, n])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::fc_batched_ref;
    use crate::ttd::decompose::random_cores;
    use crate::ttd::TtLayout;
    use crate::util::prng::Rng;

    #[test]
    fn forward_equals_dense_reconstruction() {
        let mut rng = Rng::new(31);
        for (ms, ns, r) in [
            (vec![4u64, 3], vec![5u64, 2], 2u64),
            (vec![5, 3, 2], vec![2, 7, 14], 4),
            (vec![2, 2, 2, 2], vec![3, 2, 2, 2], 3),
        ] {
            let layout = TtLayout::with_uniform_rank(ms, ns, r).unwrap();
            let tt = random_cores(&layout, &mut rng);
            let w = reconstruct(&tt.cores).unwrap();
            let x = Tensor::randn(vec![4, layout.n_total() as usize], 1.0, &mut rng);
            let got = tt_forward(&tt.cores, &x, None).unwrap();
            let want = fc_batched_ref(&w, &x, None).unwrap();
            assert!(
                got.allclose(&want, 1e-3, 1e-4),
                "{} maxdiff {}",
                layout.describe(),
                got.max_abs_diff(&want).unwrap()
            );
        }
    }

    #[test]
    fn forward_with_bias() {
        let mut rng = Rng::new(32);
        let layout = TtLayout::with_uniform_rank(vec![4, 3], vec![3, 4], 2).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let bias: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let x = Tensor::randn(vec![2, 12], 1.0, &mut rng);
        let plain = tt_forward(&tt.cores, &x, None).unwrap();
        let biased = tt_forward(&tt.cores, &x, Some(&bias)).unwrap();
        for b in 0..2 {
            for m in 0..12 {
                let d = biased.at(&[b, m]).unwrap() - plain.at(&[b, m]).unwrap();
                assert!((d - m as f32).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn batch_rows_are_independent() {
        let mut rng = Rng::new(33);
        let layout = TtLayout::with_uniform_rank(vec![5, 2], vec![2, 5], 3).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let x = Tensor::randn(vec![6, 10], 1.0, &mut rng);
        let full = tt_forward(&tt.cores, &x, None).unwrap();
        for b in 0..6 {
            let row = Tensor::from_vec(vec![1, 10], x.data()[b * 10..(b + 1) * 10].to_vec())
                .unwrap();
            let single = tt_forward(&tt.cores, &row, None).unwrap();
            for m in 0..10 {
                let a = full.at(&[b, m]).unwrap();
                let s = single.at(&[0, m]).unwrap();
                assert!((a - s).abs() < 1e-4, "b={b} m={m}: {a} vs {s}");
            }
        }
    }

    #[test]
    fn error_paths() {
        let mut rng = Rng::new(34);
        let layout = TtLayout::with_uniform_rank(vec![4, 3], vec![5, 2], 2).unwrap();
        let tt = random_cores(&layout, &mut rng);
        // wrong input width
        let x = Tensor::zeros(vec![2, 11]);
        assert!(tt_forward(&tt.cores, &x, None).is_err());
        // wrong bias length
        let x = Tensor::zeros(vec![2, 10]);
        assert!(tt_forward(&tt.cores, &x, Some(&[0.0; 5])).is_err());
        // empty cores
        assert!(reconstruct(&[]).is_err());
    }

    #[test]
    fn reconstruct_d1_is_transposed_core() {
        // single core (1, n, m, 1): W[i, j] = G[0, j, i, 0]
        let mut rng = Rng::new(35);
        let g = Tensor::randn(vec![1, 3, 4, 1], 1.0, &mut rng);
        let w = reconstruct(std::slice::from_ref(&g)).unwrap();
        assert_eq!(w.dims(), &[4, 3]);
        for i in 0..4 {
            for j in 0..3 {
                assert_eq!(
                    w.at(&[i, j]).unwrap(),
                    g.at(&[0, j, i, 0]).unwrap()
                );
            }
        }
    }
}
