//! Closed-form cost models for a TT layout (paper Eq. 4, 11, 13) and the
//! per-Einsum kernel dimensions used by the compiler and the DSE engine.

use super::TtLayout;

/// Paper Eq. 4: parameters of the factorized layer (cores + bias).
pub fn params(layout: &TtLayout) -> u64 {
    let mut total = layout.m_total(); // bias
    for t in 0..layout.d() {
        let [r0, n, m, r1] = layout.core_shape(t);
        total += (r0 * n * m * r1) as u64;
    }
    total
}

/// Parameters of the *unfactorized* layer (`M*N` weights + `M` bias).
pub fn dense_params(m: u64, n: u64) -> u64 {
    m * n + m
}

/// Paper Eq. 13: FLOPs of the Einsum at level `t` (1-based, t = 1..=d):
/// `2 * r_t * r_{t-1} * m_t*..*m_d * n_1*..*n_t`.
pub fn flops_level(layout: &TtLayout, t: usize) -> u64 {
    debug_assert!((1..=layout.d()).contains(&t));
    let ranks = layout.ranks();
    let mut term = 2 * ranks[t] * ranks[t - 1];
    for &m in &layout.m_shape()[t - 1..] {
        term *= m;
    }
    for &n in &layout.n_shape()[..t] {
        term *= n;
    }
    term
}

/// Paper Eq. 11: total FLOPs of the einsum chain plus bias adds.
pub fn flops(layout: &TtLayout) -> u64 {
    let mut total = layout.m_total(); // bias adds
    for t in 1..=layout.d() {
        total += flops_level(layout, t);
    }
    total
}

/// FLOPs of the unfactorized layer: `2*M*N` MAC + `M` bias.
pub fn dense_flops(m: u64, n: u64) -> u64 {
    2 * m * n + m
}

/// Which of the paper's three kernel variants an Einsum instance is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EinsumKind {
    /// t = d (processed first): contracted rank extent k = r_d = 1.
    First,
    /// 1 < t < d.
    Middle,
    /// t = 1 (processed last): output rank extent r = r_0 = 1.
    Final,
}

/// Concrete loop bounds of one Einsum kernel instance. The core/slab/output
/// index convention is documented once in [`crate::kernels`] (§ Data layout
/// conventions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EinsumDims {
    /// Chain position (first / middle / final).
    pub kind: EinsumKind,
    /// Output feature extent `m_t`.
    pub m: usize,
    /// Slab extent `b_t` (depends on batch and chain position).
    pub b: usize,
    /// Contracted input factor `n_t`.
    pub n: usize,
    /// Output rank extent (`r_{t-1}`; the paper Listing 2's `rt`).
    pub r: usize,
    /// Contracted rank extent (`r_t`; the paper Listing 2's `rt_1`).
    pub k: usize,
}

impl EinsumDims {
    /// FLOPs of this instance (2 per MAC).
    pub fn flops(&self) -> u64 {
        2 * (self.m * self.b * self.r * self.n * self.k) as u64
    }

    /// Bytes touched assuming each array element is loaded/stored once
    /// (compulsory traffic; f32).
    pub fn min_bytes(&self) -> u64 {
        let g = self.r * self.n * self.m * self.k;
        let input = self.b * self.n * self.k;
        let out = self.m * self.b * self.r;
        4 * (g + input + out) as u64
    }

    /// Arithmetic intensity (FLOPs per compulsory byte).
    pub fn intensity(&self) -> f64 {
        self.flops() as f64 / self.min_bytes() as f64
    }
}

/// The Einsum chain a TT layout executes for batch size `batch`, in
/// processing order (t = d down to t = 1) — paper Listing 1.
pub fn einsum_chain(layout: &TtLayout, batch: usize) -> Vec<EinsumDims> {
    let mut out = Vec::with_capacity(layout.d());
    einsum_chain_into(layout, batch, &mut out);
    out
}

/// Allocation-free variant of [`einsum_chain`]: clears and refills `out`
/// (the serving executor reuses one buffer across requests).
pub fn einsum_chain_into(layout: &TtLayout, batch: usize, out: &mut Vec<EinsumDims>) {
    out.clear();
    let d = layout.d();
    let mut cur_size = batch as u64 * layout.n_total();
    for t in (0..d).rev() {
        let [r_prev, n_t, m_t, r_t] = layout.core_shape(t);
        let b_t = cur_size / (n_t as u64 * r_t as u64);
        let kind = if t == d - 1 && d > 1 {
            EinsumKind::First
        } else if t == 0 {
            EinsumKind::Final
        } else {
            EinsumKind::Middle
        };
        out.push(EinsumDims {
            kind,
            m: m_t,
            b: b_t as usize,
            n: n_t,
            r: r_prev,
            k: r_t,
        });
        cur_size = m_t as u64 * b_t * r_prev as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::TtLayout;

    fn example() -> TtLayout {
        TtLayout::new(
            vec![5, 5, 3, 2, 2],
            vec![2, 2, 2, 7, 14],
            vec![1, 10, 10, 10, 10, 1],
        )
        .unwrap()
    }

    #[test]
    fn params_eq4_running_example() {
        // cores: 1*2*5*10 + 10*2*5*10 + 10*2*3*10 + 10*7*2*10 + 10*14*2*1
        assert_eq!(params(&example()), 300 + 100 + 1000 + 600 + 1400 + 280);
        assert_eq!(dense_params(300, 784), 300 * 784 + 300);
    }

    #[test]
    fn flops_eq11_cross_checked_with_python_fixture() {
        // mirrors python/tests/test_kernel.py::test_flops_eq11_is_sum_of_eq13_terms
        let l = TtLayout::new(vec![5, 3, 2], vec![2, 7, 14], vec![1, 4, 4, 1]).unwrap();
        let e1 = 2 * 4 * (5 * 3 * 2) * 2;
        let e2 = 2 * 4 * 4 * (3 * 2) * (2 * 7);
        let e3 = 2 * 4 * 2 * (2 * 7 * 14);
        assert_eq!(flops(&l), (5 * 3 * 2) + e1 + e2 + e3);
        assert_eq!(flops_level(&l, 1), e1);
        assert_eq!(flops_level(&l, 2), e2);
        assert_eq!(flops_level(&l, 3), e3);
    }

    #[test]
    fn chain_flops_sum_matches_eq11() {
        // chain with batch=1 must reproduce Eq. 11 exactly (minus bias adds)
        for layout in [
            example(),
            TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap(),
            TtLayout::with_uniform_rank(vec![10, 10, 3], vec![4, 8, 16], 4).unwrap(),
        ] {
            let chain = einsum_chain(&layout, 1);
            let total: u64 = chain.iter().map(|e| e.flops()).sum();
            assert_eq!(total + layout.m_total(), flops(&layout), "{}", layout.describe());
        }
    }

    #[test]
    fn chain_kinds_and_batch_scaling() {
        let l = example();
        let chain = einsum_chain(&l, 1);
        assert_eq!(chain.len(), 5);
        assert_eq!(chain[0].kind, EinsumKind::First);
        assert_eq!(chain[0].k, 1);
        assert!(matches!(chain[1].kind, EinsumKind::Middle));
        assert_eq!(chain[4].kind, EinsumKind::Final);
        assert_eq!(chain[4].r, 1);
        // doubling batch doubles every slab extent
        let chain2 = einsum_chain(&l, 2);
        for (a, b) in chain.iter().zip(&chain2) {
            assert_eq!(2 * a.b, b.b);
        }
    }

    #[test]
    fn chain_shapes_consistent() {
        // slab input size of step i+1 equals output size of step i
        let l = TtLayout::with_uniform_rank(vec![8, 8, 4], vec![4, 8, 8], 8).unwrap();
        let chain = einsum_chain(&l, 3);
        for w in chain.windows(2) {
            let out_size = w[0].m * w[0].b * w[0].r;
            let in_size = w[1].b * w[1].n * w[1].k;
            assert_eq!(out_size, in_size);
        }
        // final output size = batch * M
        let last = chain.last().unwrap();
        assert_eq!(last.m * last.b * last.r, 3 * 8 * 8 * 4);
    }

    #[test]
    fn d1_layout_is_single_final_einsum() {
        let l = TtLayout::new(vec![6], vec![9], vec![1, 1]).unwrap();
        let chain = einsum_chain(&l, 2);
        assert_eq!(chain.len(), 1);
        assert_eq!(chain[0].kind, EinsumKind::Final);
    }

    #[test]
    fn compression_wins_for_paper_example() {
        let l = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        assert!(params(&l) < dense_params(300, 784));
        assert!(flops(&l) < dense_flops(300, 784));
    }

    #[test]
    fn intensity_is_low_memory_bound() {
        // the paper calls these kernels memory-bound; check intensity < 10
        let l = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        for e in einsum_chain(&l, 1) {
            assert!(e.intensity() < 10.0, "{e:?} intensity {}", e.intensity());
        }
    }
}
