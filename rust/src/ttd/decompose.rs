//! TT-SVD: decompose a dense FC weight matrix into T3F cores
//! (Oseledets 2011, adapted to the TT-matrix index convention of
//! Novikov et al. / T3F used throughout the paper).
//!
//! The weight matrix `W (M, N)` is first regarded as a 2d-way tensor with
//! combined modes `k_t = (i_t, j_t)` (output factor major), then swept with
//! sequential truncated SVDs. The resulting cores have the T3F shape
//! `(r_{t-1}, n_t, m_t, r_t)` so they drop straight into the einsum chain,
//! the Pallas kernel, and the serving engine.

use crate::error::{Error, Result};
use crate::linalg::{truncated_svd, Svd};
use crate::tensor::Tensor;

use super::{apply, TtLayout};

/// A TT-decomposed FC layer: layout + concrete cores (+ optional bias).
#[derive(Debug, Clone)]
pub struct TtCores {
    /// The factorized layout the cores realize.
    pub layout: TtLayout,
    /// Core `t` has shape `(r_{t-1}, n_t, m_t, r_t)`.
    pub cores: Vec<Tensor>,
    /// Optional output bias (length `M`).
    pub bias: Option<Vec<f32>>,
}

impl TtCores {
    /// Total stored parameters (cores + bias).
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.numel()).sum::<usize>()
            + self.bias.as_ref().map_or(0, |b| b.len())
    }

    /// Densify back to `W (M, N)`.
    pub fn reconstruct(&self) -> Result<Tensor> {
        apply::reconstruct(&self.cores)
    }

    /// Relative Frobenius reconstruction error against the original matrix.
    pub fn rel_error(&self, w: &Tensor) -> Result<f32> {
        self.reconstruct()?.rel_l2_error(w)
    }
}

/// Rearrange `W (M, N)` into the 2d-way tensor `A[k_1, ..., k_d]` with
/// `k_t = i_t * n_t + j_t` (row-major), returned flat.
fn interleave(w: &Tensor, m_shape: &[u64], n_shape: &[u64]) -> Result<Vec<f32>> {
    let d = m_shape.len();
    let m_total: u64 = m_shape.iter().product();
    let n_total: u64 = n_shape.iter().product();
    let dims = w.dims();
    if dims != [m_total as usize, n_total as usize] {
        return Err(Error::shape(format!(
            "W {:?} incompatible with shapes m={m_shape:?} n={n_shape:?}",
            dims
        )));
    }
    // strides of the combined-mode tensor
    let combined: Vec<usize> = (0..d)
        .map(|t| (m_shape[t] * n_shape[t]) as usize)
        .collect();
    let mut a = vec![0.0f32; (m_total * n_total) as usize];
    let wd = w.data();
    let mut i_parts = vec![0usize; d];
    let mut j_parts = vec![0usize; d];
    for (lin, slot) in a.iter_mut().enumerate() {
        // decompose lin into (k_1..k_d), each k_t into (i_t, j_t)
        let mut rem = lin;
        for t in (0..d).rev() {
            let k_t = rem % combined[t];
            rem /= combined[t];
            i_parts[t] = k_t / n_shape[t] as usize;
            j_parts[t] = k_t % n_shape[t] as usize;
        }
        let mut i = 0usize;
        let mut j = 0usize;
        for t in 0..d {
            i = i * m_shape[t] as usize + i_parts[t];
            j = j * n_shape[t] as usize + j_parts[t];
        }
        *slot = wd[i * n_total as usize + j];
    }
    Ok(a)
}

/// TT-SVD of `w` targeting the given layout's shapes with intermediate
/// ranks *at most* the layout's ranks (they are clipped to the actual
/// unfolding ranks). The returned `TtCores.layout` carries the achieved
/// ranks.
pub fn tt_svd(w: &Tensor, target: &TtLayout) -> Result<TtCores> {
    let d = target.d();
    let m_shape = target.m_shape().to_vec();
    let n_shape = target.n_shape().to_vec();
    let a = interleave(w, &m_shape, &n_shape)?;
    let combined: Vec<usize> = (0..d)
        .map(|t| (m_shape[t] * n_shape[t]) as usize)
        .collect();

    let mut cores_knm: Vec<Tensor> = Vec::with_capacity(d); // (r_prev, k_t, r_t)
    let mut achieved = vec![1u64; d + 1];
    let total: usize = combined.iter().product();
    let mut cur = Tensor::from_vec(vec![combined[0], total / combined[0]], a)?;
    let mut r_prev = 1usize;
    for t in 0..d - 1 {
        let rows = cur.dims()[0];
        let cols = cur.dims()[1];
        let cap = target.ranks()[t + 1] as usize;
        let r_t = cap.min(rows).min(cols);
        let Svd { u, s, vt } = truncated_svd(&cur, r_t)?;
        let r_t = s.len();
        achieved[t + 1] = r_t as u64;
        // core_t = U reshaped (r_prev, k_t, r_t)
        cores_knm.push(u.reshape(vec![r_prev, combined[t], r_t])?);
        // cur = diag(S) * Vt, reshaped for the next unfolding
        let mut sv = vt;
        for (row, &sval) in s.iter().enumerate() {
            let cols_v = sv.dims()[1];
            for v in &mut sv.data_mut()[row * cols_v..(row + 1) * cols_v] {
                *v *= sval;
            }
        }
        let rest: usize = combined[t + 1..].iter().product();
        debug_assert_eq!(sv.numel(), r_t * rest);
        let next_cols = rest / combined[t + 1];
        cur = sv.reshape(vec![r_t * combined[t + 1], next_cols])?;
        r_prev = r_t;
        let _ = (rows, cols);
    }
    // last core: (r_prev, k_d, 1)
    cores_knm.push(cur.reshape(vec![r_prev, combined[d - 1], 1])?);

    // split k_t = (i_t, j_t) and swap to T3F order (r_prev, n_t, m_t, r_t)
    let mut cores = Vec::with_capacity(d);
    for (t, c) in cores_knm.into_iter().enumerate() {
        let r0 = achieved[t] as usize;
        let r1 = achieved[t + 1] as usize;
        let mt = m_shape[t] as usize;
        let nt = n_shape[t] as usize;
        let c = c
            .reshape(vec![r0, mt, nt, r1])?
            .transpose(&[0, 2, 1, 3])?;
        cores.push(c);
    }

    let layout = TtLayout::new(m_shape, n_shape, achieved)?;
    Ok(TtCores { layout, cores, bias: None })
}

/// Random TT cores for a layout (the Rust analogue of `t3f.random_matrix`);
/// per-core sigma chosen so the reconstructed W has roughly Glorot variance.
pub fn random_cores(layout: &TtLayout, rng: &mut crate::util::prng::Rng) -> TtCores {
    let d = layout.d();
    let m_total = layout.m_total() as f64;
    let n_total = layout.n_total() as f64;
    let rank_paths: f64 = layout.ranks()[1..d].iter().map(|&r| r as f64).product();
    let target_var = 2.0 / (m_total + n_total);
    let core_sigma = ((target_var / rank_paths).powf(1.0 / d as f64)).sqrt() as f32;
    let cores = layout
        .core_shapes()
        .into_iter()
        .map(|s| Tensor::randn(s.to_vec(), core_sigma, rng))
        .collect();
    TtCores { layout: layout.clone(), cores, bias: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::apply;
    use crate::util::prng::Rng;

    #[test]
    fn exact_recovery_of_tt_structured_matrix() {
        // build a random TT matrix of rank 3, decompose at rank >= 3: exact
        let mut rng = Rng::new(21);
        let layout = TtLayout::with_uniform_rank(vec![4, 3], vec![5, 2], 3).unwrap();
        let truth = random_cores(&layout, &mut rng);
        let w = truth.reconstruct().unwrap();
        let target = TtLayout::with_uniform_rank(vec![4, 3], vec![5, 2], 6).unwrap();
        let tt = tt_svd(&w, &target).unwrap();
        let err = tt.rel_error(&w).unwrap();
        assert!(err < 1e-4, "err {err}");
        // achieved rank must not exceed the true rank
        assert!(tt.layout.ranks()[1] <= 10);
    }

    #[test]
    fn full_rank_decomposition_is_exact() {
        let mut rng = Rng::new(22);
        let w = Tensor::randn(vec![12, 10], 1.0, &mut rng);
        // ranks high enough to be unconstrained
        let target = TtLayout::new(vec![4, 3], vec![2, 5], vec![1, 999, 1]).unwrap();
        let tt = tt_svd(&w, &target).unwrap();
        assert!(tt.rel_error(&w).unwrap() < 1e-4);
        // achieved rank clipped to min unfolding dim (4*2 = 8)
        assert_eq!(tt.layout.ranks()[1], 8);
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(vec![30, 16], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1u64, 2, 4, 8] {
            let target = TtLayout::with_uniform_rank(vec![6, 5], vec![4, 4], r).unwrap();
            let err = tt_svd(&w, &target).unwrap().rel_error(&w).unwrap();
            assert!(err <= last + 1e-5, "rank {r}: {err} > {last}");
            last = err;
        }
    }

    #[test]
    fn cores_have_layout_shapes_and_forward_works() {
        let mut rng = Rng::new(24);
        let w = Tensor::randn(vec![300, 784], 0.1, &mut rng);
        let target = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let tt = tt_svd(&w, &target).unwrap();
        for (t, c) in tt.cores.iter().enumerate() {
            assert_eq!(c.dims(), tt.layout.core_shape(t));
        }
        // forward through the einsum chain approximates dense forward
        let x = Tensor::randn(vec![3, 784], 1.0, &mut rng);
        let approx = apply::tt_forward(&tt.cores, &x, None).unwrap();
        let w_hat = tt.reconstruct().unwrap();
        let exact = crate::tensor::einsum::fc_batched_ref(&w_hat, &x, None).unwrap();
        assert!(approx.allclose(&exact, 1e-3, 1e-3));
    }

    #[test]
    fn d3_roundtrip() {
        let mut rng = Rng::new(25);
        let layout = TtLayout::with_uniform_rank(vec![3, 2, 2], vec![2, 3, 2], 2).unwrap();
        let truth = random_cores(&layout, &mut rng);
        let w = truth.reconstruct().unwrap();
        assert_eq!(w.dims(), &[12, 12]);
        let target = TtLayout::with_uniform_rank(vec![3, 2, 2], vec![2, 3, 2], 12).unwrap();
        let tt = tt_svd(&w, &target).unwrap();
        assert!(tt.rel_error(&w).unwrap() < 1e-4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let w = Tensor::zeros(vec![10, 10]);
        let target = TtLayout::with_uniform_rank(vec![5, 3], vec![5, 2], 2).unwrap();
        assert!(tt_svd(&w, &target).is_err()); // 5*3 != 10
    }

    #[test]
    fn param_count_includes_bias() {
        let mut rng = Rng::new(26);
        let layout = TtLayout::with_uniform_rank(vec![4, 3], vec![5, 2], 2).unwrap();
        let mut tt = random_cores(&layout, &mut rng);
        let base = tt.param_count();
        tt.bias = Some(vec![0.0; 12]);
        assert_eq!(tt.param_count(), base + 12);
    }
}
