//! Tensor-Train decomposition of FC layers — the T3F formulation the paper
//! builds on (paper §2).
//!
//! * [`TtLayout`] — a validated (m-shape, n-shape, rank-list) triple.
//! * [`cost`] — the paper's closed-form parameter (Eq. 4) and FLOP
//!   (Eq. 11/13) models, plus per-Einsum kernel dimensions.
//! * [`decompose`] — TT-SVD of a dense weight matrix into T3F cores.
//! * [`apply`] — reference forward pass (einsum chain, Listing 1) and dense
//!   reconstruction.

pub mod cost;
pub mod decompose;
pub mod apply;

use crate::error::{Error, Result};
use crate::factor;

/// A validated TT-matrix layout for an FC layer `y = Wx + b`,
/// `W (M, N)` with `M = prod(m_shape)`, `N = prod(n_shape)`.
///
/// Core/slab/output index conventions are documented once in
/// [`crate::kernels`] (§ Data layout conventions); `ranks` has length
/// `d + 1` with `ranks[0] == ranks[d] == 1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TtLayout {
    m_shape: Vec<u64>,
    n_shape: Vec<u64>,
    ranks: Vec<u64>,
}

impl TtLayout {
    /// A layout from explicit factor shapes and a full rank list.
    pub fn new(m_shape: Vec<u64>, n_shape: Vec<u64>, ranks: Vec<u64>) -> Result<Self> {
        let d = m_shape.len();
        if d == 0 || n_shape.len() != d {
            return Err(Error::layout(format!(
                "shape lengths differ: m {} vs n {}",
                d,
                n_shape.len()
            )));
        }
        if ranks.len() != d + 1 {
            return Err(Error::layout(format!(
                "rank list must have d+1 = {} entries, got {}",
                d + 1,
                ranks.len()
            )));
        }
        if ranks[0] != 1 || ranks[d] != 1 {
            return Err(Error::layout("boundary ranks r_0 and r_d must be 1"));
        }
        if m_shape.iter().chain(&n_shape).any(|&f| f == 0)
            || ranks.iter().any(|&r| r == 0)
        {
            return Err(Error::layout("zero factor or rank"));
        }
        Ok(TtLayout { m_shape, n_shape, ranks })
    }

    /// Layout with every intermediate rank equal to `r` (the paper's "R").
    pub fn with_uniform_rank(m_shape: Vec<u64>, n_shape: Vec<u64>, r: u64) -> Result<Self> {
        let d = m_shape.len();
        let mut ranks = vec![r; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        TtLayout::new(m_shape, n_shape, ranks)
    }

    /// Configuration length `d` (number of cores / Einsum layers).
    pub fn d(&self) -> usize {
        self.m_shape.len()
    }

    /// Output factorization `(m_1 .. m_d)`.
    pub fn m_shape(&self) -> &[u64] {
        &self.m_shape
    }

    /// Input factorization `(n_1 .. n_d)`.
    pub fn n_shape(&self) -> &[u64] {
        &self.n_shape
    }

    /// Rank list `(r_0 .. r_d)` with `r_0 = r_d = 1`.
    pub fn ranks(&self) -> &[u64] {
        &self.ranks
    }

    /// Output dimension `M`.
    pub fn m_total(&self) -> u64 {
        self.m_shape.iter().product()
    }

    /// Input dimension `N`.
    pub fn n_total(&self) -> u64 {
        self.n_shape.iter().product()
    }

    /// Core `t` (0-based) shape `(r_{t-1}, n_t, m_t, r_t)`.
    pub fn core_shape(&self, t: usize) -> [usize; 4] {
        [
            self.ranks[t] as usize,
            self.n_shape[t] as usize,
            self.m_shape[t] as usize,
            self.ranks[t + 1] as usize,
        ]
    }

    /// All core shapes, t = 0..d.
    pub fn core_shapes(&self) -> Vec<[usize; 4]> {
        (0..self.d()).map(|t| self.core_shape(t)).collect()
    }

    /// Is this layout aligned per the paper's Definition 1?
    pub fn is_aligned(&self) -> bool {
        factor::is_aligned(&self.m_shape, &self.n_shape)
    }

    /// Are all intermediate ranks within the TT rank bound?
    pub fn ranks_feasible(&self) -> bool {
        (1..self.d()).all(|t| {
            self.ranks[t] <= factor::max_rank_at(&self.m_shape, &self.n_shape, t)
        })
    }

    /// Compact display string, e.g. `m=[5,5,3]x n=[2,7,14] r=[1,8,8,1]`.
    pub fn describe(&self) -> String {
        format!(
            "m={:?} n={:?} r={:?}",
            self.m_shape, self.n_shape, self.ranks
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_running_example_layout() {
        let l = TtLayout::new(
            vec![5, 5, 3, 2, 2],
            vec![2, 2, 2, 7, 14],
            vec![1, 10, 10, 10, 10, 1],
        )
        .unwrap();
        assert_eq!(l.d(), 5);
        assert_eq!(l.m_total(), 300);
        assert_eq!(l.n_total(), 784);
        // paper Sec. 2: G^0..G^4 shapes
        assert_eq!(l.core_shape(0), [1, 2, 5, 10]);
        assert_eq!(l.core_shape(1), [10, 2, 5, 10]);
        assert_eq!(l.core_shape(2), [10, 2, 3, 10]);
        assert_eq!(l.core_shape(3), [10, 7, 2, 10]);
        assert_eq!(l.core_shape(4), [10, 14, 2, 1]);
        assert!(l.is_aligned());
    }

    #[test]
    fn validation_failures() {
        assert!(TtLayout::new(vec![2], vec![2, 2], vec![1, 1]).is_err());
        assert!(TtLayout::new(vec![2, 2], vec![2, 2], vec![1, 1]).is_err());
        assert!(TtLayout::new(vec![2, 2], vec![2, 2], vec![2, 4, 1]).is_err());
        assert!(TtLayout::new(vec![2, 2], vec![2, 2], vec![1, 0, 1]).is_err());
        assert!(TtLayout::new(vec![], vec![], vec![1]).is_err());
    }

    #[test]
    fn uniform_rank_constructor() {
        let l = TtLayout::with_uniform_rank(vec![4, 4], vec![8, 8], 16).unwrap();
        assert_eq!(l.ranks(), &[1, 16, 1]);
        assert!(l.ranks_feasible()); // bound at t=1 is min(32, 32) = 32
        let l2 = TtLayout::with_uniform_rank(vec![2, 2], vec![2, 2], 16).unwrap();
        assert!(!l2.ranks_feasible()); // bound is 4
    }

    #[test]
    fn misaligned_layout_detected() {
        let l = TtLayout::with_uniform_rank(vec![2, 5], vec![2, 2], 2).unwrap();
        assert!(!l.is_aligned()); // m ascending = not aligned
    }
}
