//! Configuration: a TOML-subset parser and the typed config the CLI,
//! DSE engine and serving coordinator consume.
//!
//! Grammar supported (sufficient for our configs, errors loudly otherwise):
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! `#` comments. No arrays-of-tables, no nested tables, no multiline.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed config: section -> key -> raw value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A TOML-subset scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A string value.
    Str(String),
    /// An integer value.
    Int(i64),
    /// A float value.
    Float(f64),
    /// A boolean value.
    Bool(bool),
}

impl Toml {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Toml> {
        let mut out = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unterminated section", lineno + 1)))?;
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::config(format!("line {}: expected key = value", lineno + 1)))?;
            let value = parse_value(value.trim())
                .map_err(|e| Error::config(format!("line {}: {e}", lineno + 1)))?;
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(out)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// String-typed lookup (None when absent or mistyped).
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Integer-typed lookup (None when absent or mistyped).
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    /// Float-typed lookup (ints coerce; None when absent or mistyped).
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    /// Bool-typed lookup (None when absent or mistyped).
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value '{s}'"))
}

// ---------------------------------------------------------------------------
// Typed configs
// ---------------------------------------------------------------------------

/// How [`crate::dse::select::select_solution`] picks from the DSE engine's
/// time-qualified survivors / Pareto frontier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectionPolicy {
    /// The paper's §6.4 policy: the most balanced (near-square) d=2
    /// solution at the requested rank — an accuracy proxy (default).
    #[default]
    Balance,
    /// The fastest modeled solution on the Pareto frontier.
    MinTime,
}

impl SelectionPolicy {
    /// Parse a policy name as written in config files / CLI flags.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "balance" => Some(SelectionPolicy::Balance),
            "min-time" => Some(SelectionPolicy::MinTime),
            _ => None,
        }
    }

    /// The config-file spelling of this policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            SelectionPolicy::Balance => "balance",
            SelectionPolicy::MinTime => "min-time",
        }
    }
}

/// DSE engine knobs (paper §4.1-4.2 constants, overridable per run).
#[derive(Debug, Clone, PartialEq)]
pub struct DseConfig {
    /// Ranks must be multiples of this (the vectorization constraint,
    /// paper Eq. 18). Must be >= 1.
    pub vl: u64,
    /// Uniform rank values to sweep. Must be non-empty, every entry >= 1.
    pub ranks: Vec<u64>,
    /// Maximum configuration length `d` to explore. Must be >= 1.
    pub d_max: usize,
    /// Scalability cut: discard `d > d_scal_limit` when the heaviest
    /// einsum is below [`DseConfig::scal_flops`] FLOPs (paper §4.2.2).
    /// Must be >= 1.
    pub d_scal_limit: usize,
    /// FLOP threshold for the scalability cut.
    pub scal_flops: u64,
    /// Batch size assumed when pricing inference. Must be >= 1.
    pub batch: usize,
    /// Stage-6 cut: discard solutions whose modeled speedup over the dense
    /// layer is below this factor. Must be >= 1.0 (1.0 = "no modeled
    /// slowdowns", the loosest meaningful setting).
    pub time_speedup_min: f64,
    /// Worker threads for parallel enumeration + pricing. Results are
    /// byte-identical for every value. Must be >= 1.
    pub dse_workers: usize,
    /// Selection policy name; must parse via [`SelectionPolicy::parse`]
    /// (`"balance"` or `"min-time"`).
    pub selection_policy: String,
    /// Rank ladder for the accuracy-aware rank sweep
    /// ([`crate::dse::sweep_ranks`]): each stage-6 survivor shape is
    /// TT-SVD-decomposed at every rank here and annotated with its
    /// relative reconstruction error. Unlike [`DseConfig::ranks`], these
    /// are *not* constrained to multiples of `vl` — low ranks trade
    /// vector-lane utilization for accuracy headroom, and the modeled-time
    /// qualification decides what survives. Must be non-empty, every
    /// entry >= 1.
    pub rank_candidates: Vec<u64>,
    /// Cap on how many distinct stage-6 survivor shapes the rank sweep
    /// decomposes (TT-SVD per shape x rank is the expensive part). Must be
    /// >= 1; the sweep reports how many shapes the cap dropped.
    pub sweep_shapes: usize,
    /// Default accuracy budget for sweep-driven selection (`compress
    /// --rank auto` without an explicit `--accuracy-budget`): the fastest
    /// swept candidate with relative reconstruction error <= this is
    /// chosen. Must be a finite value > 0 when set.
    pub accuracy_budget: Option<f64>,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            vl: 8,
            ranks: vec![8, 16, 24, 32, 40, 48, 56, 64],
            d_max: 6,
            d_scal_limit: 4,
            scal_flops: 8_000_000,
            batch: 1,
            time_speedup_min: 1.0,
            dse_workers: 1,
            selection_policy: SelectionPolicy::Balance.as_str().to_string(),
            rank_candidates: vec![2, 4, 8, 16],
            sweep_shapes: 8,
            accuracy_budget: None,
        }
    }
}

impl DseConfig {
    /// Reject configurations that would make the DSE enumerate nothing or
    /// divide by zero downstream. Called by [`load`]; call it yourself when
    /// constructing a config programmatically.
    pub fn validate(&self) -> Result<()> {
        if self.vl < 1 {
            return Err(Error::config("dse.vl must be >= 1"));
        }
        if self.d_max < 1 {
            return Err(Error::config("dse.d_max must be >= 1"));
        }
        if self.d_scal_limit < 1 {
            return Err(Error::config("dse.d_scal_limit must be >= 1"));
        }
        if self.batch < 1 {
            return Err(Error::config("dse.batch must be >= 1"));
        }
        if self.ranks.is_empty() {
            return Err(Error::config("dse.ranks must list at least one rank"));
        }
        if let Some(r) = self.ranks.iter().find(|&&r| r < 1) {
            return Err(Error::config(format!("dse.ranks entry {r} must be >= 1")));
        }
        if !(self.time_speedup_min >= 1.0 && self.time_speedup_min.is_finite()) {
            return Err(Error::config(format!(
                "dse.time_speedup_min must be a finite value >= 1.0, got {}",
                self.time_speedup_min
            )));
        }
        if self.dse_workers < 1 {
            return Err(Error::config("dse.dse_workers must be >= 1"));
        }
        if self.rank_candidates.is_empty() {
            return Err(Error::config("dse.rank_candidates must list at least one rank"));
        }
        if let Some(r) = self.rank_candidates.iter().find(|&&r| r < 1) {
            return Err(Error::config(format!("dse.rank_candidates entry {r} must be >= 1")));
        }
        if self.sweep_shapes < 1 {
            return Err(Error::config("dse.sweep_shapes must be >= 1"));
        }
        if let Some(b) = self.accuracy_budget {
            if !(b.is_finite() && b > 0.0) {
                return Err(Error::config(format!(
                    "dse.accuracy_budget must be a finite value > 0, got {b}"
                )));
            }
        }
        self.policy()?;
        Ok(())
    }

    /// The parsed selection policy. Errors on names [`DseConfig::validate`]
    /// would reject.
    pub fn policy(&self) -> Result<SelectionPolicy> {
        SelectionPolicy::parse(&self.selection_policy).ok_or_else(|| {
            Error::config(format!(
                "dse.selection_policy '{}' unknown (expected 'balance' or 'min-time')",
                self.selection_policy
            ))
        })
    }
}

/// How idle serving workers look for work on other admission shards
/// (`serve.steal` in the TOML: `"ring"` or `"off"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// Idle workers scan the other shards in ring order (the default).
    Ring,
    /// Workers consume only their home shard.
    Off,
}

impl StealPolicy {
    /// Parse the TOML string form; `None` for unknown names.
    pub fn parse(s: &str) -> Option<StealPolicy> {
        match s {
            "ring" => Some(StealPolicy::Ring),
            "off" => Some(StealPolicy::Off),
            _ => None,
        }
    }

    /// The TOML/JSON string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            StealPolicy::Ring => "ring",
            StealPolicy::Off => "off",
        }
    }
}

/// Serving coordinator knobs (v2: sharded admission, SLO batching, and the
/// model-registry cache budget ride along with the original four fields).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Largest batch a worker executes; the dynamic batcher closes a batch
    /// at this size even before the wait window expires. Must be >= 1.
    pub max_batch: usize,
    /// Max time (microseconds) a request waits for batch-mates before its
    /// non-full batch is dispatched anyway. Also the hard cap on any
    /// SLO-derived wait budget.
    pub max_wait_us: u64,
    /// Bounded admission capacity summed across all shards: submissions
    /// beyond this fail fast with a queue-full error instead of blocking.
    /// Must be >= 1.
    pub queue_cap: usize,
    /// Number of batching workers consuming the admission shards. Each
    /// worker owns a private executor (plan cache + scratch) over the
    /// `Arc`-shared compiled model, so responses are byte-identical for
    /// any worker count; throughput scales with cores. Must be >= 1.
    pub workers: usize,
    /// Admission shards. 0 = auto (one shard per worker); otherwise
    /// clamped into `[1, workers]` at server start so every shard has an
    /// owning worker to drain it at shutdown.
    pub shards: usize,
    /// Work-stealing policy for idle workers: `"ring"` (scan other shards)
    /// or `"off"`. Must name a known policy.
    pub steal: String,
    /// Default SLO budget (microseconds) stamped on requests that carry
    /// none. 0 = no SLO: requests batch under the plain `max_wait_us`
    /// window.
    pub slo_us: u64,
    /// Engine-cache memory budget in bytes for the model registry's LRU.
    /// 0 = unlimited. The currently requested model always stays resident
    /// even when it alone exceeds the budget, so a small budget degrades
    /// to reload-per-switch rather than deadlock.
    pub cache_bytes: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait_us: 500,
            queue_cap: 1024,
            workers: 1,
            shards: 0,
            steal: "ring".to_string(),
            slo_us: 0,
            cache_bytes: 0,
        }
    }
}

impl ServeConfig {
    /// Reject configurations that would deadlock or panic the coordinator
    /// at runtime (zero workers = nobody consumes the queue; zero queue
    /// capacity = every submission rejected; zero max_batch = batches can
    /// never close; an unknown steal policy = a silently ignored knob).
    /// Called by [`load`]; call it yourself when constructing a config
    /// programmatically.
    pub fn validate(&self) -> Result<()> {
        if self.workers < 1 {
            return Err(Error::config("serve.workers must be >= 1"));
        }
        if self.queue_cap < 1 {
            return Err(Error::config("serve.queue_cap must be >= 1"));
        }
        if self.max_batch < 1 {
            return Err(Error::config("serve.max_batch must be >= 1"));
        }
        if StealPolicy::parse(&self.steal).is_none() {
            return Err(Error::config(format!(
                "serve.steal '{}' unknown (expected 'ring' or 'off')",
                self.steal
            )));
        }
        Ok(())
    }

    /// The parsed steal policy. Errors with the same message as
    /// [`validate`](Self::validate) on an unknown name.
    pub fn steal_policy(&self) -> Result<StealPolicy> {
        StealPolicy::parse(&self.steal).ok_or_else(|| {
            Error::config(format!(
                "serve.steal '{}' unknown (expected 'ring' or 'off')",
                self.steal
            ))
        })
    }

    /// Effective shard count for a given worker pool: `shards = 0` means
    /// one shard per worker, and explicit counts are clamped into
    /// `[1, workers]` so every shard has an owner to drain it.
    pub fn effective_shards(&self, workers: usize) -> usize {
        let workers = workers.max(1);
        if self.shards == 0 { workers } else { self.shards.clamp(1, workers) }
    }
}

/// Measurement-harness knobs (`ttrv bench`); the `[bench]` TOML section.
///
/// ```toml
/// [bench]
/// warmup_iters = 3
/// min_iters = 10        # floor: timed iterations per measurement cell
/// min_time_ms = 200     # floor: wall-clock per measurement cell
/// trim = 0.2            # fraction trimmed from each tail
/// serve_requests = 512  # burst size per serving-sweep point
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct BenchConfig {
    /// Untimed warmup iterations per measurement cell.
    pub warmup_iters: usize,
    /// Minimum timed iterations per cell. Must be >= 1.
    pub min_iters: usize,
    /// Minimum wall-clock milliseconds per cell (the coarse-clock floor).
    pub min_time_ms: u64,
    /// Fraction trimmed from each tail of the sample set. Must be a finite
    /// value in `[0, 0.5)` (0.5+ would trim everything for even n).
    pub trim: f64,
    /// Requests fired per serving-sweep configuration. Must be >= 1.
    pub serve_requests: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            min_time_ms: 200,
            trim: 0.2,
            serve_requests: 512,
        }
    }
}

impl BenchConfig {
    /// Reject configurations that would measure nothing or trim every
    /// sample away.
    pub fn validate(&self) -> Result<()> {
        if self.min_iters < 1 {
            return Err(Error::config("bench.min_iters must be >= 1"));
        }
        if !(self.trim.is_finite() && (0.0..0.5).contains(&self.trim)) {
            return Err(Error::config(format!(
                "bench.trim must be a finite value in [0, 0.5), got {}",
                self.trim
            )));
        }
        if self.serve_requests < 1 {
            return Err(Error::config("bench.serve_requests must be >= 1"));
        }
        Ok(())
    }
}

/// Load a `[bench]` section ([`BenchConfig`]; missing keys keep defaults),
/// validated like every other config.
pub fn load_bench(text: &str) -> Result<BenchConfig> {
    let t = Toml::parse(text)?;
    let mut bench = BenchConfig::default();
    if let Some(v) = non_negative(&t, "bench", "warmup_iters")? {
        bench.warmup_iters = v as usize;
    }
    if let Some(v) = non_negative(&t, "bench", "min_iters")? {
        bench.min_iters = v as usize;
    }
    if let Some(v) = non_negative(&t, "bench", "min_time_ms")? {
        bench.min_time_ms = v;
    }
    if let Some(v) = t.get_f64("bench", "trim") {
        bench.trim = v;
    }
    if let Some(v) = non_negative(&t, "bench", "serve_requests")? {
        bench.serve_requests = v as usize;
    }
    bench.validate()?;
    Ok(bench)
}

/// A model-spec file for `ttrv compress`: names the FC stack to compress
/// when it is not a zoo model. Grammar:
///
/// ```toml
/// [model]
/// name = "my-mlp"
/// shapes = "784:300, 300:100, 100:10"   # n_in:m_out per FC layer
/// rank = 8                              # optional, CLI flag wins if absent
/// seed = 42                             # optional
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpecConfig {
    /// Model display name.
    pub name: String,
    /// FC layer shapes `(n_in, m_out)` in model order.
    pub shapes: Vec<(u64, u64)>,
    /// Requested uniform TT rank, if the file pins one.
    pub rank: Option<u64>,
    /// Demo-weight seed, if the file pins one.
    pub seed: Option<u64>,
}

/// Load a compress model-spec file ([`ModelSpecConfig`]); every shape entry
/// must be `n:m` with both dims >= 1.
pub fn load_model_spec(text: &str) -> Result<ModelSpecConfig> {
    let t = Toml::parse(text)?;
    let name = t
        .get_str("model", "name")
        .ok_or_else(|| Error::config("model spec needs model.name"))?
        .to_string();
    let raw = t
        .get_str("model", "shapes")
        .ok_or_else(|| Error::config("model spec needs model.shapes (\"n:m, n:m, ...\")"))?;
    let mut shapes = Vec::new();
    for entry in raw.split(',') {
        let entry = entry.trim();
        let (n, m) = entry
            .split_once(':')
            .ok_or_else(|| Error::config(format!("model.shapes entry '{entry}' is not n:m")))?;
        let parse = |s: &str| {
            s.trim()
                .parse::<u64>()
                .ok()
                .filter(|&v| v >= 1)
                .ok_or_else(|| {
                    Error::config(format!("model.shapes entry '{entry}': bad dimension '{s}'"))
                })
        };
        shapes.push((parse(n)?, parse(m)?));
    }
    if shapes.is_empty() {
        return Err(Error::config("model.shapes lists no layers"));
    }
    let rank = non_negative(&t, "model", "rank")?;
    if rank == Some(0) {
        return Err(Error::config("model.rank must be >= 1"));
    }
    let seed = non_negative(&t, "model", "seed")?;
    Ok(ModelSpecConfig { name, shapes, rank, seed })
}

/// A non-negative integer field (negative values would otherwise wrap
/// through the unsigned cast and dodge validation).
fn non_negative(t: &Toml, section: &str, key: &str) -> Result<Option<u64>> {
    match t.get_int(section, key) {
        None => Ok(None),
        Some(v) => u64::try_from(v)
            .map(Some)
            .map_err(|_| Error::config(format!("{section}.{key} must be >= 0, got {v}"))),
    }
}

/// Load DSE + serve configs from a TOML-subset file. Both configs are
/// validated ([`DseConfig::validate`] / [`ServeConfig::validate`]): a file
/// that would panic or deadlock the runtime is rejected here, loudly.
pub fn load(text: &str) -> Result<(DseConfig, ServeConfig)> {
    let t = Toml::parse(text)?;
    let mut dse = DseConfig::default();
    if let Some(v) = non_negative(&t, "dse", "vl")? {
        dse.vl = v;
    }
    if let Some(v) = non_negative(&t, "dse", "d_max")? {
        dse.d_max = v as usize;
    }
    if let Some(v) = non_negative(&t, "dse", "batch")? {
        dse.batch = v as usize;
    }
    if let Some(v) = non_negative(&t, "dse", "scal_flops")? {
        dse.scal_flops = v;
    }
    if let Some(v) = t.get_str("dse", "ranks") {
        dse.ranks = v
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<u64>()
                    .map_err(|e| Error::config(format!("dse.ranks entry '{}': {e}", x.trim())))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(v) = t.get_f64("dse", "time_speedup_min") {
        dse.time_speedup_min = v;
    }
    if let Some(v) = non_negative(&t, "dse", "dse_workers")? {
        dse.dse_workers = v as usize;
    }
    if let Some(v) = t.get_str("dse", "selection_policy") {
        dse.selection_policy = v.to_string();
    }
    if let Some(v) = t.get_str("dse", "rank_candidates") {
        dse.rank_candidates = v
            .split(',')
            .map(|x| {
                x.trim().parse::<u64>().map_err(|e| {
                    Error::config(format!("dse.rank_candidates entry '{}': {e}", x.trim()))
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(v) = non_negative(&t, "dse", "sweep_shapes")? {
        dse.sweep_shapes = v as usize;
    }
    if let Some(v) = t.get_f64("dse", "accuracy_budget") {
        dse.accuracy_budget = Some(v);
    }
    let mut serve = ServeConfig::default();
    if let Some(v) = non_negative(&t, "serve", "max_batch")? {
        serve.max_batch = v as usize;
    }
    if let Some(v) = non_negative(&t, "serve", "max_wait_us")? {
        serve.max_wait_us = v;
    }
    if let Some(v) = non_negative(&t, "serve", "queue_cap")? {
        serve.queue_cap = v as usize;
    }
    if let Some(v) = non_negative(&t, "serve", "workers")? {
        serve.workers = v as usize;
    }
    if let Some(v) = non_negative(&t, "serve", "shards")? {
        serve.shards = v as usize;
    }
    if let Some(v) = t.get_str("serve", "steal") {
        serve.steal = v.to_string();
    }
    if let Some(v) = non_negative(&t, "serve", "slo_us")? {
        serve.slo_us = v;
    }
    if let Some(v) = non_negative(&t, "serve", "cache_bytes")? {
        serve.cache_bytes = v;
    }
    dse.validate()?;
    serve.validate()?;
    Ok((dse, serve))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let t = Toml::parse(
            r#"
            # comment
            [dse]
            vl = 8
            ranks = "8, 16"   # inline comment
            frac = 0.5
            [serve]
            max_batch = 32
            debug = true
            name = "a # not comment"
            "#,
        )
        .unwrap();
        assert_eq!(t.get_int("dse", "vl"), Some(8));
        assert_eq!(t.get_str("dse", "ranks"), Some("8, 16"));
        assert_eq!(t.get_f64("dse", "frac"), Some(0.5));
        assert_eq!(t.get_bool("serve", "debug"), Some(true));
        assert_eq!(t.get_str("serve", "name"), Some("a # not comment"));
        assert_eq!(t.get("nope", "x"), None);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Toml::parse("[open").is_err());
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("x = \"unterminated").is_err());
        assert!(Toml::parse("x = what").is_err());
    }

    #[test]
    fn typed_load_roundtrip() {
        let (dse, serve) = load(
            r#"
            [dse]
            vl = 4
            ranks = "8, 24"
            batch = 16
            [serve]
            max_batch = 8
            workers = 2
            shards = 2
            steal = "off"
            slo_us = 4000
            cache_bytes = 1048576
            "#,
        )
        .unwrap();
        assert_eq!(dse.vl, 4);
        assert_eq!(dse.ranks, vec![8, 24]);
        assert_eq!(dse.batch, 16);
        assert_eq!(serve.max_batch, 8);
        assert_eq!(serve.workers, 2);
        assert_eq!(serve.shards, 2);
        assert_eq!(serve.steal_policy().unwrap(), StealPolicy::Off);
        assert_eq!(serve.slo_us, 4000);
        assert_eq!(serve.cache_bytes, 1_048_576);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let (dse, serve) = load("").unwrap();
        assert_eq!(dse, DseConfig::default());
        assert_eq!(serve, ServeConfig::default());
    }

    #[test]
    fn load_rejects_degenerate_serve_configs() {
        for (text, needle) in [
            ("[serve]\nworkers = 0", "workers"),
            ("[serve]\nqueue_cap = 0", "queue_cap"),
            ("[serve]\nmax_batch = 0", "max_batch"),
            ("[serve]\nworkers = -4", "workers"),
            ("[serve]\nsteal = \"random\"", "steal"),
            ("[serve]\nshards = -1", "shards"),
            ("[serve]\nslo_us = -5", "slo_us"),
            ("[serve]\ncache_bytes = -1", "cache_bytes"),
        ] {
            let err = load(text).expect_err(text).to_string();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn load_rejects_degenerate_dse_configs() {
        for (text, needle) in [
            ("[dse]\nvl = 0", "vl"),
            ("[dse]\nd_max = 0", "d_max"),
            ("[dse]\nbatch = 0", "batch"),
            ("[dse]\nbatch = -1", "batch"),
            ("[dse]\nranks = \"\"", "ranks"),
            ("[dse]\nranks = \"8, 0\"", "rank"),
            ("[dse]\ntime_speedup_min = 0.5", "time_speedup_min"),
            ("[dse]\ntime_speedup_min = -2.0", "time_speedup_min"),
            ("[dse]\ndse_workers = 0", "dse_workers"),
            ("[dse]\ndse_workers = -3", "dse_workers"),
            ("[dse]\nselection_policy = \"fastest\"", "selection_policy"),
            ("[dse]\nrank_candidates = \"\"", "rank_candidates"),
            ("[dse]\nrank_candidates = \"4, 0\"", "rank_candidates"),
            ("[dse]\nsweep_shapes = 0", "sweep_shapes"),
            ("[dse]\naccuracy_budget = 0.0", "accuracy_budget"),
            ("[dse]\naccuracy_budget = -0.5", "accuracy_budget"),
        ] {
            let err = load(text).expect_err(text).to_string();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn dse_engine_knobs_roundtrip() {
        let (dse, _) = load(
            r#"
            [dse]
            time_speedup_min = 2.5
            dse_workers = 4
            selection_policy = "min-time"
            rank_candidates = "2, 8, 32"
            sweep_shapes = 4
            accuracy_budget = 0.25
            "#,
        )
        .unwrap();
        assert_eq!(dse.time_speedup_min, 2.5);
        assert_eq!(dse.dse_workers, 4);
        assert_eq!(dse.policy().unwrap(), SelectionPolicy::MinTime);
        assert_eq!(dse.rank_candidates, vec![2, 8, 32]);
        assert_eq!(dse.sweep_shapes, 4);
        assert_eq!(dse.accuracy_budget, Some(0.25));
        // integer-typed threshold coerces like any float knob
        let (dse, _) = load("[dse]\ntime_speedup_min = 3").unwrap();
        assert_eq!(dse.time_speedup_min, 3.0);
        // ...and so does the accuracy budget; absent means no default budget
        let (dse, _) = load("[dse]\naccuracy_budget = 1").unwrap();
        assert_eq!(dse.accuracy_budget, Some(1.0));
        assert_eq!(DseConfig::default().accuracy_budget, None);
    }

    #[test]
    fn selection_policy_parse_roundtrip() {
        for p in [SelectionPolicy::Balance, SelectionPolicy::MinTime] {
            assert_eq!(SelectionPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(SelectionPolicy::parse("fastest"), None);
        assert_eq!(SelectionPolicy::default(), SelectionPolicy::Balance);
        let bad = DseConfig { selection_policy: "fastest".into(), ..Default::default() };
        assert!(bad.policy().is_err());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn bench_config_loads_and_validates() {
        let b = load_bench(
            r#"
            [bench]
            warmup_iters = 1
            min_iters = 4
            min_time_ms = 30
            trim = 0.1
            serve_requests = 64
            "#,
        )
        .unwrap();
        assert_eq!(b.warmup_iters, 1);
        assert_eq!(b.min_iters, 4);
        assert_eq!(b.min_time_ms, 30);
        assert_eq!(b.trim, 0.1);
        assert_eq!(b.serve_requests, 64);
        // defaults when the section is absent
        assert_eq!(load_bench("").unwrap(), BenchConfig::default());
        BenchConfig::default().validate().unwrap();
        // degenerate knobs rejected loudly
        for (text, needle) in [
            ("[bench]\nmin_iters = 0", "min_iters"),
            ("[bench]\nmin_iters = -3", "min_iters"),
            ("[bench]\ntrim = 0.5", "trim"),
            ("[bench]\ntrim = -0.1", "trim"),
            ("[bench]\nserve_requests = 0", "serve_requests"),
        ] {
            let err = load_bench(text).expect_err(text).to_string();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn model_spec_loads_and_validates() {
        let spec = load_model_spec(
            r#"
            [model]
            name = "my-mlp"
            shapes = "784:300, 300:100, 100:10"
            rank = 8
            seed = 42
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "my-mlp");
        assert_eq!(spec.shapes, vec![(784, 300), (300, 100), (100, 10)]);
        assert_eq!(spec.rank, Some(8));
        assert_eq!(spec.seed, Some(42));
        // optional knobs may be absent
        let spec = load_model_spec("[model]\nname = \"x\"\nshapes = \"64:64\"").unwrap();
        assert_eq!(spec.rank, None);
        assert_eq!(spec.seed, None);
    }

    #[test]
    fn model_spec_rejects_malformed() {
        for text in [
            "",                                                // no section
            "[model]\nshapes = \"10:10\"",                     // no name
            "[model]\nname = \"x\"",                           // no shapes
            "[model]\nname = \"x\"\nshapes = \"10x10\"",       // not n:m
            "[model]\nname = \"x\"\nshapes = \"10:0\"",        // zero dim
            "[model]\nname = \"x\"\nshapes = \"10:ten\"",      // non-numeric
            "[model]\nname = \"x\"\nshapes = \"10:10\"\nrank = 0",
            "[model]\nname = \"x\"\nshapes = \"10:10\"\nrank = -2",
        ] {
            assert!(load_model_spec(text).is_err(), "accepted: {text}");
        }
    }

    #[test]
    fn validate_accepts_defaults() {
        DseConfig::default().validate().unwrap();
        ServeConfig::default().validate().unwrap();
        let s = ServeConfig { workers: 0, ..Default::default() };
        assert!(s.validate().is_err());
        let s = ServeConfig { steal: "chaos".to_string(), ..Default::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn effective_shards_clamps_to_worker_pool() {
        let auto = ServeConfig::default(); // shards = 0 -> one per worker
        assert_eq!(auto.effective_shards(4), 4);
        assert_eq!(auto.effective_shards(1), 1);
        let pinned = ServeConfig { shards: 8, ..Default::default() };
        // never more shards than workers: every shard needs an owner to
        // drain it at shutdown
        assert_eq!(pinned.effective_shards(3), 3);
        assert_eq!(pinned.effective_shards(16), 8);
        let one = ServeConfig { shards: 1, ..Default::default() };
        assert_eq!(one.effective_shards(4), 1);
        let d = DseConfig { time_speedup_min: f64::NAN, ..Default::default() };
        assert!(d.validate().is_err());
        let d = DseConfig { time_speedup_min: f64::INFINITY, ..Default::default() };
        assert!(d.validate().is_err());
    }
}
