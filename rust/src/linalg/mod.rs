//! Numerical linear algebra substrate: blocked matmul and a one-sided
//! Jacobi SVD (no LAPACK offline). Powers TT-SVD decomposition
//! ([`crate::ttd::decompose`]) and the dense baselines.

mod matmul;
mod svd;

pub use matmul::{matmul, matmul_naive};
pub use svd::{svd, truncated_svd, Svd};
