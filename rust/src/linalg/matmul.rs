//! Dense matrix multiplication.
//!
//! `matmul` is a cache-blocked i-k-j kernel used by the SVD, the IREE-like
//! baseline's MMM stage and the e2e trainer. It is deliberately *not* the
//! paper's optimized einsum engine (that lives in [`crate::kernels`]) — it is
//! the generic substrate.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Naive triple loop, kept as the correctness oracle for `matmul`.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    let (ad, bd) = (a.data(), b.data());
    let mut out = Tensor::zeros(vec![m, n]);
    let od = out.data_mut();
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += ad[i * k + p] * bd[p * n + j];
            }
            od[i * n + j] = acc;
        }
    }
    Ok(out)
}

/// Cache-blocked i-k-j matmul (`C = A B`, A `(m, k)`, B `(k, n)`).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    let (ad, bd) = (a.data(), b.data());
    let mut out = Tensor::zeros(vec![m, n]);
    let od = out.data_mut();
    // block sizes sized for a ~32 KiB L1: 64*64*4 B tiles
    const BI: usize = 64;
    const BK: usize = 64;
    for i0 in (0..m).step_by(BI) {
        let i1 = (i0 + BI).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in i0..i1 {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut od[i * n..(i + 1) * n];
                for p in k0..k1 {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &bd[p * n..(p + 1) * n];
                    // j loop vectorizes (contiguous fma over crow/brow)
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
        }
    }
    Ok(out)
}

fn check_dims(a: &Tensor, b: &Tensor) -> Result<(usize, usize, usize)> {
    let (da, db) = (a.dims(), b.dims());
    if da.len() != 2 || db.len() != 2 || da[1] != db[0] {
        return Err(Error::shape(format!("matmul dims {:?} x {:?}", da, db)));
    }
    Ok((da[0], da[1], db[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(vec![4, 4], 1.0, &mut rng);
        let mut eye = Tensor::zeros(vec![4, 4]);
        for i in 0..4 {
            *eye.at_mut(&[i, i]).unwrap() = 1.0;
        }
        let c = matmul(&a, &eye).unwrap();
        assert!(c.allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn blocked_matches_naive_on_awkward_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 70, 5), (65, 64, 63), (130, 7, 129)] {
            let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
            let fast = matmul(&a, &b).unwrap();
            let slow = matmul_naive(&a, &b).unwrap();
            assert!(
                fast.allclose(&slow, 1e-4, 1e-4),
                "mismatch at ({m},{k},{n}): {}",
                fast.max_abs_diff(&slow).unwrap()
            );
        }
    }

    #[test]
    fn rejects_bad_dims() {
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![4, 2]);
        assert!(matmul(&a, &b).is_err());
        let v = Tensor::zeros(vec![3]);
        assert!(matmul(&a, &v).is_err());
    }
}
