//! One-sided (Hestenes) Jacobi SVD.
//!
//! LAPACK is unavailable offline, and TT-SVD only needs thin SVDs of
//! moderate unfoldings, for which cyclic one-sided Jacobi is simple, robust
//! and accurate (dot products are accumulated in f64).

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Thin SVD `A = U diag(S) V^T` with `A (m, n)`, `U (m, p)`, `S (p)`,
/// `V^T (p, n)` and `p = min(m, n)`; singular values sorted descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors `U (m, p)`.
    pub u: Tensor,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// Right singular vectors `V^T (p, n)`.
    pub vt: Tensor,
}

impl Svd {
    /// Reconstruct the (possibly truncated) matrix `U diag(S) V^T`.
    pub fn reconstruct(&self) -> Result<Tensor> {
        let p = self.s.len();
        let m = self.u.dims()[0];
        let n = self.vt.dims()[1];
        let (ud, vd) = (self.u.data(), self.vt.data());
        let mut out = Tensor::zeros(vec![m, n]);
        let od = out.data_mut();
        for (k, &sk) in self.s.iter().enumerate().take(p) {
            for i in 0..m {
                let uik = ud[i * p + k] * sk;
                if uik == 0.0 {
                    continue;
                }
                let vrow = &vd[k * n..(k + 1) * n];
                let orow = &mut od[i * n..(i + 1) * n];
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o += uik * v;
                }
            }
        }
        Ok(out)
    }

    /// Keep only the top `r` singular triplets.
    pub fn truncate(mut self, r: usize) -> Svd {
        let p = self.s.len();
        let r = r.min(p);
        let m = self.u.dims()[0];
        let n = self.vt.dims()[1];
        let mut u = Tensor::zeros(vec![m, r]);
        for i in 0..m {
            for k in 0..r {
                u.data_mut()[i * r + k] = self.u.data()[i * p + k];
            }
        }
        let vt_data = self.vt.data()[..r * n].to_vec();
        self.u = u;
        self.s.truncate(r);
        self.vt = Tensor::from_vec(vec![r, n], vt_data).expect("vt slice");
        self
    }
}

/// Compute the thin SVD of `a` via cyclic one-sided Jacobi.
pub fn svd(a: &Tensor) -> Result<Svd> {
    let d = a.dims();
    if d.len() != 2 {
        return Err(Error::shape(format!("svd expects a matrix, got {:?}", d)));
    }
    let (m, n) = (d[0], d[1]);
    if m == 0 || n == 0 {
        return Err(Error::shape("svd of empty matrix"));
    }
    if m >= n {
        svd_tall(a)
    } else {
        // A = U S V^T  <=>  A^T = V S U^T
        let at = a.transpose(&[1, 0])?;
        let Svd { u, s, vt } = svd_tall(&at)?;
        Ok(Svd { u: vt.transpose(&[1, 0])?, s, vt: u.transpose(&[1, 0])? })
    }
}

/// One-sided Jacobi for `m >= n`: rotate column pairs of A until all are
/// mutually orthogonal; then `sigma_j = ||a_j||`, `u_j = a_j / sigma_j`.
fn svd_tall(a: &Tensor) -> Result<Svd> {
    let d = a.dims();
    let (m, n) = (d[0], d[1]);
    debug_assert!(m >= n);
    // Work on A^T so columns of A are contiguous rows.
    let mut at = a.transpose(&[1, 0])?.into_vec(); // (n, m) row-major
    let mut vt = vec![0.0f32; n * n]; // V^T, rows are v_j^T
    for j in 0..n {
        vt[j * n + j] = 1.0;
    }

    const MAX_SWEEPS: usize = 60;
    let tol = 1e-9f64;
    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64; // largest |gamma| / sqrt(alpha*beta) this sweep
        for i in 0..n {
            for j in (i + 1)..n {
                let (alpha, beta, gamma) = {
                    let ci = &at[i * m..(i + 1) * m];
                    let cj = &at[j * m..(j + 1) * m];
                    let mut alpha = 0.0f64;
                    let mut beta = 0.0f64;
                    let mut gamma = 0.0f64;
                    for (x, y) in ci.iter().zip(cj) {
                        alpha += (*x as f64) * (*x as f64);
                        beta += (*y as f64) * (*y as f64);
                        gamma += (*x as f64) * (*y as f64);
                    }
                    (alpha, beta, gamma)
                };
                if alpha == 0.0 || beta == 0.0 {
                    continue;
                }
                let rel = gamma.abs() / (alpha * beta).sqrt();
                off = off.max(rel);
                if rel <= tol {
                    continue;
                }
                // Jacobi rotation zeroing the (i, j) Gram entry
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate_rows(&mut at, m, i, j, c as f32, s as f32);
                rotate_rows(&mut vt, n, i, j, c as f32, s as f32);
            }
        }
        if off <= tol {
            break;
        }
    }

    // Singular values = column norms; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| {
            at[j * m..(j + 1) * m]
                .iter()
                .map(|x| (*x as f64) * (*x as f64))
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).expect("NaN in svd"));

    let mut u = Tensor::zeros(vec![m, n]);
    let mut s = vec![0.0f32; n];
    let mut vt_sorted = Tensor::zeros(vec![n, n]);
    for (slot, &j) in order.iter().enumerate() {
        let norm = norms[j];
        s[slot] = norm as f32;
        let col = &at[j * m..(j + 1) * m];
        if norm > 0.0 {
            let inv = (1.0 / norm) as f32;
            for (row, &v) in col.iter().enumerate() {
                u.data_mut()[row * n + slot] = v * inv;
            }
        }
        vt_sorted.data_mut()[slot * n..(slot + 1) * n]
            .copy_from_slice(&vt[j * n..(j + 1) * n]);
    }
    Ok(Svd { u, s, vt: vt_sorted })
}

/// Apply the Givens rotation to rows `i`, `j` of a row-major `(rows, width)`
/// buffer: `(ri, rj) <- (c*ri - s*rj, s*ri + c*rj)`.
fn rotate_rows(buf: &mut [f32], width: usize, i: usize, j: usize, c: f32, s: f32) {
    debug_assert_ne!(i, j);
    let (lo, hi) = if i < j { (i, j) } else { (j, i) };
    let (head, tail) = buf.split_at_mut(hi * width);
    let ri = &mut head[lo * width..(lo + 1) * width];
    let rj = &mut tail[..width];
    if lo == i {
        for (x, y) in ri.iter_mut().zip(rj.iter_mut()) {
            let (xi, yj) = (*x, *y);
            *x = c * xi - s * yj;
            *y = s * xi + c * yj;
        }
    } else {
        for (y, x) in ri.iter_mut().zip(rj.iter_mut()) {
            let (xi, yj) = (*x, *y);
            *x = c * xi - s * yj;
            *y = s * xi + c * yj;
        }
    }
}

/// SVD truncated to rank `r`.
pub fn truncated_svd(a: &Tensor, r: usize) -> Result<Svd> {
    Ok(svd(a)?.truncate(r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::prng::Rng;

    fn assert_orthonormal_cols(t: &Tensor, tol: f32) {
        let g = matmul(&t.transpose(&[1, 0]).unwrap(), t).unwrap();
        let p = g.dims()[0];
        for i in 0..p {
            for j in 0..p {
                let want = if i == j { 1.0 } else { 0.0 };
                let got = g.at(&[i, j]).unwrap();
                assert!((got - want).abs() < tol, "gram[{i},{j}]={got}");
            }
        }
    }

    #[test]
    fn reconstructs_random_tall_matrix() {
        let mut rng = Rng::new(10);
        let a = Tensor::randn(vec![20, 8], 1.0, &mut rng);
        let f = svd(&a).unwrap();
        let back = f.reconstruct().unwrap();
        assert!(
            back.rel_l2_error(&a).unwrap() < 1e-4,
            "err {}",
            back.rel_l2_error(&a).unwrap()
        );
        assert_orthonormal_cols(&f.u, 1e-4);
        assert_orthonormal_cols(&f.vt.transpose(&[1, 0]).unwrap(), 1e-4);
        // descending
        for w in f.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn reconstructs_wide_matrix() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(vec![6, 17], 1.0, &mut rng);
        let f = svd(&a).unwrap();
        assert_eq!(f.u.dims(), &[6, 6]);
        assert_eq!(f.vt.dims(), &[6, 17]);
        let back = f.reconstruct().unwrap();
        assert!(back.rel_l2_error(&a).unwrap() < 1e-4);
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Tensor::zeros(vec![4, 4]);
        for (i, &v) in [3.0f32, 7.0, 1.0, 5.0].iter().enumerate() {
            *a.at_mut(&[i, i]).unwrap() = v;
        }
        let f = svd(&a).unwrap();
        assert!((f.s[0] - 7.0).abs() < 1e-5);
        assert!((f.s[1] - 5.0).abs() < 1e-5);
        assert!((f.s[2] - 3.0).abs() < 1e-5);
        assert!((f.s[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_recovers_exact_low_rank() {
        // A = u v^T (rank 1) reconstructed exactly from rank-1 truncation
        let mut rng = Rng::new(12);
        let u = Tensor::randn(vec![15, 1], 1.0, &mut rng);
        let v = Tensor::randn(vec![1, 9], 1.0, &mut rng);
        let a = matmul(&u, &v).unwrap();
        let f = truncated_svd(&a, 1).unwrap();
        assert_eq!(f.s.len(), 1);
        let back = f.reconstruct().unwrap();
        assert!(back.rel_l2_error(&a).unwrap() < 1e-4);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(vec![30, 30], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1usize, 5, 15, 30] {
            let back = truncated_svd(&a, r).unwrap().reconstruct().unwrap();
            let err = back.rel_l2_error(&a).unwrap();
            assert!(err <= last + 1e-6, "rank {r}: {err} > {last}");
            last = err;
        }
        assert!(last < 1e-4); // full rank is exact
    }

    #[test]
    fn truncation_error_matches_tail_energy() {
        // Eckart–Young: ||A - A_r||_F^2 = sum_{i>r} sigma_i^2
        let mut rng = Rng::new(14);
        let a = Tensor::randn(vec![12, 10], 1.0, &mut rng);
        let f = svd(&a).unwrap();
        let r = 4;
        let back = f.clone().truncate(r).reconstruct().unwrap();
        let mut diff2 = 0.0f64;
        for (x, y) in back.data().iter().zip(a.data()) {
            diff2 += ((x - y) as f64).powi(2);
        }
        let tail2: f64 = f.s[r..].iter().map(|&s| (s as f64).powi(2)).sum();
        assert!(
            (diff2 - tail2).abs() / tail2.max(1e-12) < 1e-3,
            "{diff2} vs {tail2}"
        );
    }

    #[test]
    fn rejects_non_matrices() {
        assert!(svd(&Tensor::zeros(vec![2, 2, 2])).is_err());
    }
}
