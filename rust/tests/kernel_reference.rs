//! Tier-2 kernel verification: the **tolerance differential suite**.
//!
//! Every registered microkernel (portable, and the host's vector kernel
//! when one is supported) is run against the scalar canonical reference
//! (`naive_einsum`) over the 24 pinned Table-3 shapes x all three `G`
//! layouts, plus remainder-tile edge shapes that leave partial register
//! tiles, partial r lane groups, and k-loop scalar tails.
//!
//! Vector kernels (FMA, lane-split reductions) legitimately move the
//! low-order bits of an f32 reduction, so this suite does **not** demand
//! bitwise equality — that is tier 1, pinned forced-scalar by
//! `executor_suite.rs` / `serving.rs` / `artifact_suite.rs`. Instead each
//! output element is held to a *principled* forward-error bound derived
//! from its reduction depth `L = n * k`:
//!
//! ```text
//! |computed - exact| <= gamma_L * sum |g * x|,
//!     gamma_L = L*u / (1 - L*u),  u = f32 unit roundoff = EPSILON / 2
//! ```
//!
//! which holds for *any* summation order (and for FMA contractions) of L
//! products (Higham, *Accuracy and Stability of Numerical Algorithms*,
//! ch. 3). Both the reference and the candidate satisfy it vs the exact
//! sum, so their difference is bounded by `2 * gamma_L * sum|g*x|`; the
//! absolute floor covers the all-zero / subnormal corner. No magic
//! epsilons: a kernel that reassociates is fine, a kernel that drops or
//! double-counts a term is ~L/2 times over this bound and fails loudly.
//!
//! The int8 twin suite holds every kernel's quantized path (`execute_q`
//! over `quantize(pack(g))`) to the same bound **plus** the per-`m`-slice
//! quantization step: symmetric rounding perturbs each `g` element by at
//! most `scale[m] / 2`, so an output element of slice `m` moves by at
//! most `(scale[m] / 2) * sum_{n,k} |x|` on top of the accumulation term.

use ttrv::compiler::cb_suite;
use ttrv::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
use ttrv::kernels::{pack, quantize, Executor, Kernel, VL};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};
use ttrv::util::prng::Rng;

/// Keep the full 24-shape sweep fast: the bound is per-element, so the
/// batch extent only multiplies runtime, not coverage.
const B_CAP: usize = 48;

#[allow(clippy::too_many_arguments)]
fn plan_with(
    dims: EinsumDims,
    pack_g: bool,
    vloop: VectorLoop,
    rb: RbFactors,
    threads: u32,
) -> OptimizationPlan {
    OptimizationPlan {
        dims,
        pack_g,
        vector_loop: vloop,
        vl: if vloop == VectorLoop::None { 1 } else { VL },
        rb,
        tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
        threads,
        ls_estimate: 0,
    }
}

/// `2 * gamma_L * sum|g*x|` per output element, plus a subnormal floor.
fn tolerances(g: &Tensor, x: &Tensor, reduction_depth: usize) -> Vec<f32> {
    let abs = |t: &Tensor| {
        Tensor::from_vec(t.dims().to_vec(), t.data().iter().map(|v| v.abs()).collect()).unwrap()
    };
    let u = f32::EPSILON as f64 / 2.0;
    let lu = reduction_depth as f64 * u;
    assert!(lu < 0.5, "reduction depth {reduction_depth} too deep for a meaningful f32 bound");
    let gamma = lu / (1.0 - lu);
    ttrv::kernels::naive_einsum(&abs(g), &abs(x))
        .unwrap()
        .data()
        .iter()
        .map(|&s| (2.0 * gamma * s as f64) as f32 + f32::MIN_POSITIVE)
        .collect()
}

/// Run `plan` on an executor pinned to `kernel` and check every element
/// against the reference within its per-element bound.
fn check_plan(
    kernel: &'static dyn Kernel,
    plan: OptimizationPlan,
    g: &Tensor,
    x: &Tensor,
    want: &[f32],
    tol: &[f32],
    label: &str,
) {
    let machine = MachineSpec::spacemit_k1();
    let mut ex = Executor::with_kernel(&machine, kernel).unwrap();
    let pg = pack(g, &plan).unwrap();
    ex.set_plan(plan).unwrap();
    let got = ex.execute(&plan.dims, &pg, x).unwrap();
    assert_eq!(got.data().len(), want.len(), "{label}: wrong output size");
    for (i, ((&a, &w), &t)) in got.data().iter().zip(want).zip(tol).enumerate() {
        assert!(
            (a - w).abs() <= t,
            "kernel {} {label}: elem {i}: got {a}, want {w}, |diff| {} > tol {t}",
            kernel.name(),
            (a - w).abs()
        );
    }
}

fn kind_of(r: usize, k: usize) -> EinsumKind {
    if k == 1 {
        EinsumKind::First
    } else if r == 1 {
        EinsumKind::Final
    } else {
        EinsumKind::Middle
    }
}

/// Run one (dims) case through every layout x blocking flavor for every
/// registered, supported kernel.
fn sweep_case(dims: EinsumDims, rng: &mut Rng, label: &str) {
    let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, rng);
    let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, rng);
    let want = ttrv::kernels::naive_einsum(&g, &x).unwrap();
    let tol = tolerances(&g, &x, dims.n * dims.k);
    for &kernel in ttrv::kernels::all_kernels() {
        if !kernel.supported() {
            continue;
        }
        // Canonical (naive loop nest, kernel-independent by construction)
        let naive = OptimizationPlan::naive(dims);
        check_plan(kernel, naive, &g, &x, want.data(), &tol, &format!("{label} canonical"));
        // PackedK scalar + k-vectorized
        for vloop in [VectorLoop::None, VectorLoop::K] {
            let p = plan_with(dims, true, vloop, RbFactors::NONE, 1);
            check_plan(kernel, p, &g, &x, want.data(), &tol, &format!("{label} {vloop:?}"));
        }
        // PackedR r-vectorized across register-tile shapes, including ones
        // that leave remainder tiles on the pinned m/b extents
        for (rm, rb) in [(1usize, 1usize), (2, 3), (4, 2), (8, 8)] {
            let rbf = RbFactors { rm, rb, rr: 1, rk: 1 };
            let p = plan_with(dims, true, VectorLoop::R, rbf, 1);
            check_plan(
                kernel,
                p,
                &g,
                &x,
                want.data(),
                &tol,
                &format!("{label} R rb=({rm},{rb})"),
            );
        }
        // one threaded PackedR flavor: partitioning must not break dispatch
        let p = plan_with(dims, true, VectorLoop::R, RbFactors { rm: 4, rb: 4, rr: 1, rk: 1 }, 2);
        check_plan(kernel, p, &g, &x, want.data(), &tol, &format!("{label} R T=2"));
    }
}

/// Int8 bound: the f32 differential bound plus the quantization step.
/// For an output element of slice `m` (output layout `[m, b, r]`),
/// symmetric rounding moves each `g` element by at most `scale[m] / 2`,
/// contributing at most `(scale[m] / 2) * sum_{n,k} |x[b,n,k]|`; the
/// `1.01` factor absorbs the `gamma_L` cross-term on the perturbation.
fn tolerances_q(g: &Tensor, x: &Tensor, scales: &[f32], dims: &EinsumDims) -> Vec<f32> {
    let base = tolerances(g, x, dims.n * dims.k);
    let slab = dims.n * dims.k;
    let xd = x.data();
    let abs_x: Vec<f32> = (0..dims.b)
        .map(|bi| xd[bi * slab..(bi + 1) * slab].iter().map(|v| v.abs()).sum())
        .collect();
    base.iter()
        .enumerate()
        .map(|(i, &t)| {
            let mi = i / (dims.b * dims.r);
            let bi = (i / dims.r) % dims.b;
            t + 1.01 * 0.5 * scales[mi] * abs_x[bi]
        })
        .collect()
}

/// Quantized twin of [`check_plan`]: pack for `plan`, quantize the packed
/// core, run the kernel's int8 path via `execute_q`, and hold every
/// element to the int8 per-element bound.
fn check_plan_q(
    kernel: &'static dyn Kernel,
    plan: OptimizationPlan,
    g: &Tensor,
    x: &Tensor,
    want: &[f32],
    tol: &[f32],
    label: &str,
) {
    let machine = MachineSpec::spacemit_k1();
    let mut ex = Executor::with_kernel(&machine, kernel).unwrap();
    let qg = quantize(&pack(g, &plan).unwrap());
    ex.set_plan(plan).unwrap();
    let got = ex.execute_q(&plan.dims, &qg, x).unwrap();
    assert_eq!(got.data().len(), want.len(), "{label}: wrong output size");
    for (i, ((&a, &w), &t)) in got.data().iter().zip(want).zip(tol).enumerate() {
        assert!(
            (a - w).abs() <= t,
            "kernel {} int8 {label}: elem {i}: got {a}, want {w}, |diff| {} > tol {t}",
            kernel.name(),
            (a - w).abs()
        );
    }
}

/// Quantized twin of [`sweep_case`]: one (dims) case through every layout
/// x blocking flavor for every registered kernel's int8 path. Slice
/// scales are layout-independent (the per-`m` amax is the same set of
/// values in any packing), so one canonical quantize pins the bound.
fn sweep_case_q(dims: EinsumDims, rng: &mut Rng, label: &str) {
    let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, rng);
    let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, rng);
    let want = ttrv::kernels::naive_einsum(&g, &x).unwrap();
    let scales = quantize(&pack(&g, &OptimizationPlan::naive(dims)).unwrap()).scales;
    let tol = tolerances_q(&g, &x, &scales, &dims);
    for &kernel in ttrv::kernels::all_kernels() {
        if !kernel.supported() {
            continue;
        }
        let naive = OptimizationPlan::naive(dims);
        check_plan_q(kernel, naive, &g, &x, want.data(), &tol, &format!("{label} canonical"));
        for vloop in [VectorLoop::None, VectorLoop::K] {
            let p = plan_with(dims, true, vloop, RbFactors::NONE, 1);
            check_plan_q(kernel, p, &g, &x, want.data(), &tol, &format!("{label} {vloop:?}"));
        }
        for (rm, rb) in [(1usize, 1usize), (2, 3), (4, 2), (8, 8)] {
            let rbf = RbFactors { rm, rb, rr: 1, rk: 1 };
            let p = plan_with(dims, true, VectorLoop::R, rbf, 1);
            check_plan_q(
                kernel,
                p,
                &g,
                &x,
                want.data(),
                &tol,
                &format!("{label} R rb=({rm},{rb})"),
            );
        }
        let p = plan_with(dims, true, VectorLoop::R, RbFactors { rm: 4, rb: 4, rr: 1, rk: 1 }, 2);
        check_plan_q(kernel, p, &g, &x, want.data(), &tol, &format!("{label} R T=2"));
    }
}

/// All 24 pinned Table-3 shapes x 3 G layouts x every registered kernel.
#[test]
fn differential_suite_on_pinned_table3_shapes() {
    let mut rng = Rng::new(0x5eed_d1ff);
    for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
        for e in cb_suite(kind) {
            let mut dims = e.dims;
            dims.b = dims.b.min(B_CAP);
            sweep_case(dims, &mut rng, &e.id);
        }
    }
}

/// Int8 twin of the 24-shape sweep: every kernel's quantized path over
/// the same pinned Table-3 shapes x 3 G layouts, held to the f32 bound
/// plus the per-slice quantization step.
#[test]
fn differential_suite_int8_on_pinned_table3_shapes() {
    let mut rng = Rng::new(0x18_d1ff ^ 0x5eed_0000);
    for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
        for e in cb_suite(kind) {
            let mut dims = e.dims;
            dims.b = dims.b.min(B_CAP);
            sweep_case_q(dims, &mut rng, &e.id);
        }
    }
}

/// Int8 twin of the remainder-tile sweep: quantized pad lanes (zeroed by
/// construction) and scalar tails must not leak into live outputs.
#[test]
fn differential_suite_int8_on_remainder_edge_shapes() {
    let mut rng = Rng::new(0x1a7e_17e8);
    for (m, b, n, r, k) in [
        (1usize, 1usize, 1usize, 1usize, 1usize),
        (7, 13, 3, 8, 8),
        (9, 5, 2, 16, 8),
        (4, 6, 2, 12, 8),  // r_pad 16 > r: masked final lane group
        (5, 4, 3, 8, 12),  // k tail of 4 past the last full VL chunk
        (2, 9, 1, 3, 5),   // nothing divides anything
    ] {
        let dims = EinsumDims { kind: kind_of(r, k), m, b, n, r, k };
        sweep_case_q(dims, &mut rng, &format!("edge {m}x{b}x{n}x{r}x{k}"));
    }
}

/// Remainder-tile edge shapes: prime m/b (partial register tiles), r not a
/// VL multiple (partial lane group + zero padding), k with a scalar tail
/// for the k-vectorized kernel, and degenerate all-1 extents.
#[test]
fn differential_suite_on_remainder_edge_shapes() {
    let mut rng = Rng::new(0x7a11_ed9e);
    for (m, b, n, r, k) in [
        (1usize, 1usize, 1usize, 1usize, 1usize),
        (1, 1, 1, 8, 8),
        (7, 13, 3, 8, 8),
        (9, 5, 2, 16, 8),
        (3, 2, 1, 8, 16),
        (5, 3, 2, 8, 1),
        (6, 4, 3, 1, 8),
        (4, 6, 2, 12, 8),  // r_pad 16 > r: masked final lane group
        (5, 4, 3, 8, 12),  // k tail of 4 past the last full VL chunk
        (2, 9, 1, 3, 5),   // nothing divides anything
        (17, 1, 2, 8, 8),  // single-slab batch, prime m
    ] {
        let dims = EinsumDims { kind: kind_of(r, k), m, b, n, r, k };
        sweep_case(dims, &mut rng, &format!("edge {m}x{b}x{n}x{r}x{k}"));
    }
}

/// The portable kernel is not merely close — on the non-reassociating
/// paths (canonical, PackedK scalar, PackedR r-vectorized) it is the
/// bitwise reference the tier-1 suites pin. Guard that here so a refactor
/// of the portable lane loops can't silently change the reference bits
/// while the differential suite keeps passing.
#[test]
fn portable_kernel_is_bitwise_reference_on_order_preserving_paths() {
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(0xb17_b17);
    for (m, b, n, r, k) in
        [(7usize, 11usize, 3usize, 8usize, 8usize), (9, 5, 2, 16, 8), (4, 6, 2, 12, 8)]
    {
        let dims = EinsumDims { kind: kind_of(r, k), m, b, n, r, k };
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let want = ttrv::kernels::naive_einsum(&g, &x).unwrap().into_vec();
        let mut ex = Executor::with_kernel(&machine, ttrv::kernels::portable()).unwrap();
        for (pack_g, vloop, rb) in [
            (false, VectorLoop::None, RbFactors::NONE),
            (true, VectorLoop::None, RbFactors::NONE),
            (true, VectorLoop::R, RbFactors::NONE),
            (true, VectorLoop::R, RbFactors { rm: 2, rb: 3, rr: 1, rk: 1 }),
        ] {
            let plan = plan_with(dims, pack_g, vloop, rb, 1);
            let pg = pack(&g, &plan).unwrap();
            ex.set_plan(plan).unwrap();
            let got = ex.execute(&dims, &pg, &x).unwrap().into_vec();
            assert_eq!(got, want, "portable not bitwise on {dims:?} {vloop:?} pack={pack_g}");
        }
    }
}
