//! Integration suite for the `ttrv bench` measurement subsystem (ISSUE 5):
//! the harness must produce schema-valid, deterministic-field-order
//! `BENCH_*.json` files, respect the measurement floor, and never emit
//! NaN/inf into a report.

use std::time::Duration;

use ttrv::bench::harness::{
    self, kernel_report_json, kernel_rows, run_serve_sweep, serve_report_json, write_report,
    ServePoint, BENCH_KERNELS_SCHEMA_VERSION, BENCH_SERVE_SCHEMA_VERSION,
};
use ttrv::bench::BenchCfg;
use ttrv::baselines::dense::DenseFc;
use ttrv::compiler::cb_suite;
use ttrv::coordinator::{LayerOp, ModelEngine};
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::EinsumKind;
use ttrv::util::json::{self, Json};

fn tiny_cfg() -> BenchCfg {
    BenchCfg { warmup_iters: 1, min_iters: 3, min_time: Duration::from_millis(1), trim: 0.2 }
}

fn toy_engine(name: &str) -> ModelEngine {
    let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
    let fc = DenseFc::new(&w, None).unwrap();
    ModelEngine::new(name, vec![LayerOp::Dense(fc)], 4, 2)
}

/// Every number reachable in a report must be finite (util/json writes
/// non-finite as null, but the harness should not rely on that for its
/// regular fields).
fn assert_all_numbers_finite(v: &Json, path: &str) {
    match v {
        Json::Num(n) => assert!(n.is_finite(), "{path} = {n}"),
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                assert_all_numbers_finite(item, &format!("{path}[{i}]"));
            }
        }
        Json::Obj(map) => {
            for (k, val) in map {
                assert_all_numbers_finite(val, &format!("{path}.{k}"));
            }
        }
        _ => {}
    }
}

#[test]
fn bench_files_are_written_schema_valid_and_reparseable() {
    let dir = std::env::temp_dir().join(format!("ttrv_bench_harness_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // kernel report over a pinned-shape subset (b capped to keep CI fast)
    let suite = cb_suite(EinsumKind::Middle);
    let rows = kernel_rows(&suite[..2], Some(16), &tiny_cfg()).unwrap();
    let kernels = kernel_report_json(&rows, true);
    let kpath = dir.join(harness::BENCH_KERNELS_FILE);
    write_report(&kpath, &kernels).unwrap();

    // serve report over a 2-point grid (single- and two-model) on
    // deterministic toy engines
    let engines = [toy_engine("toy"), toy_engine("toy2")];
    let points = [
        ServePoint { workers: 1, max_batch: 4, models: 1 },
        ServePoint { workers: 2, max_batch: 8, models: 2 },
    ];
    let (srows, snapshot) = run_serve_sweep(&engines, &points, 32).unwrap();
    let serve = serve_report_json(&srows, true, &snapshot);
    let spath = dir.join(harness::BENCH_SERVE_FILE);
    write_report(&spath, &serve).unwrap();

    for (path, schema, version, doc) in [
        (&kpath, "ttrv-bench-kernels", BENCH_KERNELS_SCHEMA_VERSION, &kernels),
        (&spath, "ttrv-bench-serve", BENCH_SERVE_SCHEMA_VERSION, &serve),
    ] {
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.ends_with('\n'), "{}: report must end with a newline", path.display());
        let back = json::parse(&text).unwrap();
        assert_eq!(&back, doc, "{}: file does not round-trip", path.display());
        assert_eq!(back.get("schema").unwrap().as_str(), Some(schema));
        assert_eq!(back.get("schema_version").unwrap().as_u64(), Some(version));
        assert_eq!(back.get("quick").unwrap().as_bool(), Some(true));
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert!(!results.is_empty());
        assert_all_numbers_finite(&back, schema);
    }

    // serve v2 specifics: per-row model axis + the embedded snapshot
    let sback = json::parse(&std::fs::read_to_string(&spath).unwrap()).unwrap();
    let models = sback.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2, "both co-hosted model names must be listed");
    for row in sback.get("results").unwrap().as_arr().unwrap() {
        assert!(row.get("model").unwrap().as_str().is_some());
        assert!(row.get("models").unwrap().as_usize().unwrap() >= 1);
    }
    let snap = sback.get("snapshot").unwrap();
    assert_eq!(snap.get("schema").unwrap().as_str(), Some("ttrv-serve-snapshot"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn report_field_order_is_deterministic() {
    // the same rows must serialize to the same bytes, twice — the property
    // the trajectory diffs rely on (util/json sorts object keys)
    let suite = cb_suite(EinsumKind::First);
    let rows = kernel_rows(&suite[..1], Some(8), &tiny_cfg()).unwrap();
    let a = json::to_string_pretty(&kernel_report_json(&rows, true));
    let b = json::to_string_pretty(&kernel_report_json(&rows, true));
    assert_eq!(a, b);
}

#[test]
fn measurement_floor_is_respected_per_cell() {
    let cfg = BenchCfg {
        warmup_iters: 0,
        min_iters: 7,
        min_time: Duration::from_millis(2),
        trim: 0.2,
    };
    let suite = cb_suite(EinsumKind::Final);
    let rows = kernel_rows(&suite[..1], Some(4), &cfg).unwrap();
    for m in [&rows[0].ours, &rows[0].iree_like, &rows[0].pluto_like] {
        assert!(m.iters >= 7, "{}: only {} timed iterations", m.name, m.iters);
        assert!(m.seconds.is_finite() && m.min.is_finite());
    }
}

#[test]
fn serve_sweep_scales_input_order_independently() {
    // two runs of the same point produce the same request count and
    // answer everything (timings vary; correctness may not)
    let engines = [toy_engine("toy")];
    let p = [ServePoint { workers: 2, max_batch: 4, models: 1 }];
    let (a, _) = run_serve_sweep(&engines, &p, 16).unwrap();
    let (b, _) = run_serve_sweep(&engines, &p, 16).unwrap();
    assert_eq!(a[0].requests, b[0].requests);
    assert!(a[0].req_per_s > 0.0 && b[0].req_per_s > 0.0);
}
