//! The `.ttrv` artifact test suite (ISSUE 4):
//!
//! * **Round-trip properties** — randomized d ∈ {2..4}, non-uniform ranks,
//!   prime-mixed factor shapes, all three `G` layouts: write → read →
//!   serve must be bitwise-identical to the in-memory engine.
//! * **Corruption/fuzz decoding** — truncated files, bit-flipped bytes,
//!   oversized TOC/length fields and zero-byte files must all return the
//!   typed `Error::Artifact` — never panic, never OOM.
//! * **Golden artifact** — `tests/data/lenet300.ttrv` is pinned: today's
//!   reader must load it and serve the pinned output vector. This is the
//!   forward-compat tripwire for every future format change.
//! * **End-to-end** — compress → file → `Server::from_artifact` serves
//!   bitwise-identically to the freshly compressed engine.
//!
//! This binary is a **tier-1 bitwise pin**: every test that executes an
//! engine runs forced-scalar (portable kernel), so the golden-artifact and
//! replay assertions hold byte-for-byte on any host. Vector-kernel accuracy
//! is tier 2, covered by `kernel_reference.rs`.

use std::sync::OnceLock;

use ttrv::artifact::format::{crc32, put_u32, put_u64, HEADER_LEN, MAGIC, TOC_ENTRY_LEN};
use ttrv::artifact::{self, BundleOp, CompressSpec, ModelBundle, TtLayerBundle};
use ttrv::compiler::OptimizationPlan;
use ttrv::config::DseConfig;
use ttrv::coordinator::{InferenceRequest, Server, TtFcEngine};
use ttrv::dse::{Solution, TimedSolution};
use ttrv::error::Error;
use ttrv::kernels::{pack, Executor, GLayout};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::einsum_chain;
use ttrv::ttd::decompose::{random_cores, TtCores};
use ttrv::ttd::TtLayout;
use ttrv::util::json::Json;
use ttrv::util::prng::Rng;

fn k1() -> MachineSpec {
    MachineSpec::spacemit_k1()
}

/// Pin this process to the portable reference kernel (first statement of
/// every kernel-executing test here — tests run concurrently and the flag
/// is global, but it is only ever raised, never lowered, so there is no
/// race).
fn force_scalar() {
    ttrv::kernels::set_force_scalar(true);
}

/// One compressed LeNet300, shared across the tests that need a real
/// DSE-produced bundle (compression runs the full engine per layer).
fn lenet_bundle() -> &'static ModelBundle {
    static BUNDLE: OnceLock<ModelBundle> = OnceLock::new();
    BUNDLE.get_or_init(|| {
        let spec = CompressSpec::from_zoo("lenet300", 8, 42).unwrap();
        artifact::compress(&spec, &k1(), &DseConfig::default()).unwrap()
    })
}

/// Wrap one TT layer (cores packed per `plans`) into a single-layer bundle.
fn single_layer_bundle(tt: &TtCores, plans: Vec<OptimizationPlan>) -> ModelBundle {
    let layout = tt.layout.clone();
    let packed = plans
        .iter()
        .enumerate()
        .map(|(step, plan)| pack(&tt.cores[layout.d() - 1 - step], plan).unwrap())
        .collect();
    let max_rank = layout.ranks().iter().copied().max().unwrap();
    let selected = TimedSolution {
        solution: Solution::new(layout.clone(), max_rank),
        time_s: 1e-4,
        speedup: 2.0,
    };
    ModelBundle {
        name: format!("single-{}", layout.describe()),
        machine: k1().name.to_string(),
        in_dim: layout.n_total() as usize,
        out_dim: layout.m_total() as usize,
        rank: max_rank,
        seed: 0,
        shapes: vec![(layout.n_total(), layout.m_total())],
        ops: vec![BundleOp::Tt(TtLayerBundle {
            layout,
            packed,
            plans,
            bias: tt.bias.clone(),
            selected,
            tuned: None,
            quant: None,
        })],
        report: Json::Arr(vec![]),
        tuned_kernel: None,
        auto: None,
    }
}

fn compiled_plans(layout: &TtLayout, machine: &MachineSpec) -> Vec<OptimizationPlan> {
    let mut ex = Executor::new(machine);
    einsum_chain(layout, 1).iter().map(|d| ex.plan(d).unwrap()).collect()
}

fn assert_bitwise_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.dims(), b.dims(), "{ctx}: dims differ");
    for (i, (va, vb)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}: element {i}: {va} vs {vb}");
    }
}

// ---------------------------------------------------------------------------
// Round-trip properties
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_randomized_layouts_serve_bitwise() {
    force_scalar();
    // d ∈ {2, 3, 4}, non-uniform ranks, prime-mixed factor shapes
    let cases: Vec<TtLayout> = vec![
        TtLayout::new(vec![7, 11], vec![13, 5], vec![1, 6, 1]).unwrap(),
        TtLayout::new(vec![5, 3, 4], vec![4, 7, 3], vec![1, 5, 3, 1]).unwrap(),
        TtLayout::new(vec![3, 2, 5, 2], vec![2, 3, 2, 7], vec![1, 4, 7, 2, 1]).unwrap(),
    ];
    let machine = k1();
    let mut rng = Rng::new(2024);
    for layout in cases {
        let mut tt = random_cores(&layout, &mut rng);
        tt.bias = Some(rng.normal_vec(layout.m_total() as usize, 0.1));
        let bundle = single_layer_bundle(&tt, compiled_plans(&layout, &machine));
        // write -> read restores every field
        let bytes = artifact::write_bundle(&bundle);
        let back = artifact::read_bundle_bytes(&bytes).unwrap();
        assert_eq!(back, bundle, "{}", layout.describe());
        // ...and serves bitwise-identically to the in-memory engine
        let mut from_file = back.build_engine(&machine).unwrap();
        let mut in_memory = TtFcEngine::new(&tt, &machine).unwrap();
        for batch in [1usize, 3] {
            let x = Tensor::randn(vec![batch, layout.n_total() as usize], 1.0, &mut rng);
            let got = from_file.forward(&x).unwrap();
            let want = in_memory.forward(&x).unwrap();
            assert_bitwise_eq(&got, &want, &format!("{} batch {batch}", layout.describe()));
        }
    }
}

#[test]
fn all_three_g_layouts_roundtrip() {
    force_scalar();
    let machine = k1();
    let mut rng = Rng::new(77);
    // compiled plans on a d=3 chain produce PackedR (first/middle) and
    // PackedK (final, r = 1)
    let layout = TtLayout::new(vec![6, 5, 4], vec![4, 5, 6], vec![1, 8, 8, 1]).unwrap();
    let tt = random_cores(&layout, &mut rng);
    let compiled = single_layer_bundle(&tt, compiled_plans(&layout, &machine));
    let layouts: Vec<GLayout> = match &compiled.ops[0] {
        BundleOp::Tt(t) => t.packed.iter().map(|p| p.layout).collect(),
        _ => unreachable!(),
    };
    assert!(layouts.contains(&GLayout::PackedR), "{layouts:?}");
    assert!(layouts.contains(&GLayout::PackedK), "{layouts:?}");
    let back = artifact::read_bundle_bytes(&artifact::write_bundle(&compiled)).unwrap();
    assert_eq!(back, compiled);

    // Canonical: the naive-plan (ablation) configuration round-trips too
    let naive_plans: Vec<OptimizationPlan> =
        einsum_chain(&layout, 1).into_iter().map(OptimizationPlan::naive).collect();
    let naive_bundle = single_layer_bundle(&tt, naive_plans.clone());
    match &naive_bundle.ops[0] {
        BundleOp::Tt(t) => {
            assert!(t.packed.iter().all(|p| p.layout == GLayout::Canonical))
        }
        _ => unreachable!(),
    }
    let back = artifact::read_bundle_bytes(&artifact::write_bundle(&naive_bundle)).unwrap();
    assert_eq!(back, naive_bundle);
    // the Canonical engine serves (batch 1: the preseeded naive plans) and
    // matches the in-memory naive-plan engine bitwise + the reference
    let mut from_file = back.build_engine(&machine).unwrap();
    let (packed, bias) = match naive_bundle.ops.into_iter().next().unwrap() {
        BundleOp::Tt(t) => (t.packed, t.bias),
        _ => unreachable!(),
    };
    let mut in_memory =
        TtFcEngine::from_parts(layout.clone(), packed, &naive_plans, bias, &machine).unwrap();
    let x = Tensor::randn(vec![1, layout.n_total() as usize], 1.0, &mut rng);
    let got = from_file.forward(&x).unwrap();
    let want = in_memory.forward(&x).unwrap();
    assert_bitwise_eq(&got, &want, "canonical layout");
    let w = tt.reconstruct().unwrap();
    let reference = ttrv::tensor::einsum::fc_batched_ref(&w, &x, None).unwrap();
    assert!(got.allclose(&reference, 1e-3, 1e-3));
}

#[test]
fn full_model_bundle_roundtrips_and_serves() {
    force_scalar();
    let bundle = lenet_bundle();
    let bytes = artifact::write_bundle(bundle);
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(&back, bundle);
    let mut from_file = back.build_engine(&k1()).unwrap();
    let mut in_memory = bundle.build_engine(&k1()).unwrap();
    let mut rng = Rng::new(3);
    for batch in [1usize, 5] {
        let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
        let got = from_file.forward(&x).unwrap();
        let want = in_memory.forward(&x).unwrap();
        assert_bitwise_eq(&got, &want, &format!("lenet300 batch {batch}"));
    }
}

#[test]
fn verify_passes_on_a_written_and_reloaded_bundle() {
    force_scalar();
    let bundle = lenet_bundle();
    let back = artifact::read_bundle_bytes(&artifact::write_bundle(bundle)).unwrap();
    let report = artifact::verify(&back, &k1(), &DseConfig::default()).unwrap();
    assert_eq!(report.fc_layers, 3);
    assert_eq!(report.tt_layers, 2);
    assert!(report.outputs_checked > 0);
}

// ---------------------------------------------------------------------------
// Version / magic rejection
// ---------------------------------------------------------------------------

#[test]
fn wrong_version_is_rejected_with_a_typed_error() {
    let mut bytes = artifact::write_bundle(lenet_bundle());
    bytes[4..8].copy_from_slice(&(artifact::FORMAT_VERSION + 1).to_le_bytes());
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn wrong_magic_is_rejected_with_a_typed_error() {
    let mut bytes = artifact::write_bundle(lenet_bundle());
    bytes[0..4].copy_from_slice(b"NOPE");
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("magic"), "{err}");
}

// ---------------------------------------------------------------------------
// Corruption / fuzz decoding
// ---------------------------------------------------------------------------

#[test]
fn zero_byte_and_truncated_files_are_typed_errors() {
    assert!(matches!(
        artifact::read_bundle_bytes(&[]).unwrap_err(),
        Error::Artifact(_)
    ));
    let bytes = artifact::write_bundle(lenet_bundle());
    for cut in [1usize, 4, 8, 15, 16, 40, HEADER_LEN + 3 * TOC_ENTRY_LEN, bytes.len() / 2, bytes.len() - 1] {
        let err = artifact::read_bundle_bytes(&bytes[..cut]).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "cut at {cut}: {err}");
    }
}

#[test]
fn appended_trailing_garbage_is_rejected() {
    // bytes past the last section are covered by no checksum, so the
    // container must require sections to reach the end of the file
    let mut bytes = artifact::write_bundle(lenet_bundle());
    bytes.extend_from_slice(b"junk");
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("trailing"), "{err}");
}

#[test]
fn unchecksummed_interior_gap_is_rejected() {
    // a TOC that leaves a hole between sections hides bytes no CRC
    // covers; the container requires exact tiling of the payload area
    let meta = valid_meta();
    let ops = {
        let mut ops = Vec::new();
        put_u32(&mut ops, 1);
        ops.push(2); // relu
        ops
    };
    let report = b"[]".to_vec();
    let gap = 7u64; // bytes of hidden garbage between META and OPS
    let sections = [(1u32, &meta), (2u32, &ops), (3u32, &report)];
    let mut toc = Vec::new();
    let mut offset = (HEADER_LEN + sections.len() * TOC_ENTRY_LEN) as u64;
    for (i, (id, payload)) in sections.iter().enumerate() {
        if i == 1 {
            offset += gap;
        }
        put_u32(&mut toc, *id);
        put_u32(&mut toc, crc32(payload));
        put_u64(&mut toc, offset);
        put_u64(&mut toc, payload.len() as u64);
        offset += payload.len() as u64;
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&MAGIC);
    put_u32(&mut bytes, artifact::FORMAT_VERSION);
    put_u32(&mut bytes, sections.len() as u32);
    put_u32(&mut bytes, crc32(&toc));
    bytes.extend_from_slice(&toc);
    bytes.extend_from_slice(&meta);
    bytes.extend_from_slice(&[0xAB; 7]); // the hidden bytes
    bytes.extend_from_slice(&ops);
    bytes.extend_from_slice(&report);
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("gap"), "{err}");
}

#[test]
fn unrepresentable_seed_is_rejected_at_compress_time() {
    // seeds beyond 2^53 would not survive the JSON round-trip; compress
    // must refuse rather than write a bundle its own reader rejects
    let spec = CompressSpec {
        name: "x".into(),
        shapes: vec![(784, 300)],
        rank: 8,
        seed: u64::MAX,
    };
    assert!(spec.validate().is_err());
}

#[test]
fn bit_flips_anywhere_are_detected() {
    let bytes = artifact::write_bundle(lenet_bundle());
    let mut offsets: Vec<usize> = (0..bytes.len().min(96)).collect();
    offsets.extend((96..bytes.len()).step_by(97));
    for off in offsets {
        let mut corrupt = bytes.clone();
        corrupt[off] ^= 0xFF;
        let err = artifact::read_bundle_bytes(&corrupt)
            .expect_err(&format!("flip at byte {off} went undetected"));
        assert!(matches!(err, Error::Artifact(_)), "flip at {off}: {err}");
    }
}

/// Build a container by hand (valid header, TOC and CRCs) around raw
/// section payloads, so the interior grammar can be attacked while every
/// checksum is correct.
fn container(sections: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let mut toc = Vec::new();
    let mut offset = (HEADER_LEN + sections.len() * TOC_ENTRY_LEN) as u64;
    for (id, payload) in sections {
        put_u32(&mut toc, *id);
        put_u32(&mut toc, crc32(payload));
        put_u64(&mut toc, offset);
        put_u64(&mut toc, payload.len() as u64);
        offset += payload.len() as u64;
    }
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, artifact::FORMAT_VERSION);
    put_u32(&mut out, sections.len() as u32);
    put_u32(&mut out, crc32(&toc));
    out.extend_from_slice(&toc);
    for (_, payload) in sections {
        out.extend_from_slice(payload);
    }
    out
}

fn valid_meta() -> Vec<u8> {
    br#"{"format":"ttrv-bundle","model":"x","machine":"SpacemiT-K1","in_dim":4,"out_dim":2,"rank":8,"seed":0,"shapes":[[4,2]]}"#.to_vec()
}

#[test]
fn oversized_toc_length_fails_before_allocation() {
    // a TOC entry claiming a u64::MAX-byte payload must die on the bounds
    // check (with a correct TOC CRC, so the check is actually reached)
    let mut toc = Vec::new();
    put_u32(&mut toc, 1);
    put_u32(&mut toc, 0);
    put_u64(&mut toc, (HEADER_LEN + TOC_ENTRY_LEN) as u64);
    put_u64(&mut toc, u64::MAX);
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&MAGIC);
    put_u32(&mut bytes, artifact::FORMAT_VERSION);
    put_u32(&mut bytes, 1);
    put_u32(&mut bytes, crc32(&toc));
    bytes.extend_from_slice(&toc);
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
}

#[test]
fn huge_interior_length_fields_fail_before_allocation() {
    // crafted OPS payloads with absurd counts; CRCs are all valid so the
    // decoder reaches its interior length validation
    let huge_op_count = {
        let mut ops = Vec::new();
        put_u32(&mut ops, u32::MAX);
        ops
    };
    let huge_dense = {
        let mut ops = Vec::new();
        put_u32(&mut ops, 1);
        ops.push(1); // dense tag
        put_u64(&mut ops, 1 << 31); // m
        put_u64(&mut ops, 1 << 31); // n -> m*n floats would be 2^62
        ops
    };
    let zero_d_tt = {
        let mut ops = Vec::new();
        put_u32(&mut ops, 1);
        ops.push(0); // tt tag
        put_u32(&mut ops, 0); // d = 0
        ops
    };
    let huge_rank_tt = {
        // valid-looking layout whose interior rank would overflow the
        // chain-size arithmetic at engine-construction time
        let mut ops = Vec::new();
        put_u32(&mut ops, 1);
        ops.push(0); // tt tag
        put_u32(&mut ops, 2); // d = 2
        for v in [65535u64, 65535] {
            put_u64(&mut ops, v); // m_shape
        }
        for v in [65535u64, 65535] {
            put_u64(&mut ops, v); // n_shape
        }
        for v in [1u64, u32::MAX as u64, 1] {
            put_u64(&mut ops, v); // ranks
        }
        ops
    };
    let huge_bias = {
        let mut ops = Vec::new();
        put_u32(&mut ops, 1);
        ops.push(1); // dense tag
        put_u64(&mut ops, 2); // m
        put_u64(&mut ops, 2); // n
        for _ in 0..4 {
            ops.extend_from_slice(&1.0f32.to_le_bytes());
        }
        ops.push(1); // bias present
        put_u64(&mut ops, u64::MAX); // bias length
        ops
    };
    for (what, ops) in [
        ("op count", huge_op_count),
        ("dense dims", huge_dense),
        ("tt d=0", zero_d_tt),
        ("tt huge rank", huge_rank_tt),
        ("bias length", huge_bias),
    ] {
        let bytes = container(&[(1, valid_meta()), (2, ops), (3, b"[]".to_vec())]);
        let err = artifact::read_bundle_bytes(&bytes)
            .expect_err(&format!("{what} accepted"));
        assert!(matches!(err, Error::Artifact(_)), "{what}: {err}");
    }
}

#[test]
fn trailing_garbage_in_ops_is_rejected() {
    let mut ops = Vec::new();
    put_u32(&mut ops, 1);
    ops.push(2); // relu
    ops.push(0xAB); // trailing junk
    let bytes = container(&[(1, valid_meta()), (2, ops), (3, b"[]".to_vec())]);
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("trailing"), "{err}");
}

// ---------------------------------------------------------------------------
// TUNE section (format v2: persisted measured plans)
// ---------------------------------------------------------------------------

use ttrv::artifact::format::SEC_TUNE;
use ttrv::util::timer::MeasureFloor;

/// Rebuild a written bundle's container with its TUNE payload transformed
/// (CRCs fixed up), so the section grammar can be attacked independently
/// of the checksum layer.
fn with_patched_tune(bytes: &[u8], f: impl FnOnce(&mut Vec<u8>)) -> Vec<u8> {
    let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let mut sections = Vec::with_capacity(count);
    for i in 0..count {
        let e = &bytes[HEADER_LEN + i * TOC_ENTRY_LEN..HEADER_LEN + (i + 1) * TOC_ENTRY_LEN];
        let id = u32::from_le_bytes(e[0..4].try_into().unwrap());
        let off = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
        let mut payload = bytes[off..off + len].to_vec();
        if id == SEC_TUNE {
            f(&mut payload);
        }
        sections.push((id, payload));
    }
    container(&sections)
}

/// A single-layer bundle whose TUNE section simply repeats the analytic
/// plans (a legal tuning outcome) — deterministic, no measurement needed.
fn tuned_single_layer_bundle() -> ModelBundle {
    let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
    let mut rng = Rng::new(31);
    let tt = random_cores(&layout, &mut rng);
    let plans = compiled_plans(&layout, &k1());
    let mut bundle = single_layer_bundle(&tt, plans.clone());
    match &mut bundle.ops[0] {
        BundleOp::Tt(t) => t.tuned = Some(plans),
        _ => unreachable!(),
    }
    bundle
}

#[test]
fn tune_section_roundtrips_and_is_optional() {
    force_scalar();
    // without tuned plans: no TUNE section in the container
    let untuned = lenet_bundle();
    let bytes = artifact::write_bundle(untuned);
    let ids: Vec<u32> = artifact::list_sections(&bytes).unwrap().iter().map(|s| s.id).collect();
    assert!(!ids.contains(&SEC_TUNE), "{ids:?}");

    // with measured plans: the section appears and round-trips exactly
    let mut tuned = untuned.clone();
    let report = artifact::tune_bundle(&mut tuned, &k1(), &MeasureFloor::quick()).unwrap();
    assert_eq!(report.layers, 2);
    assert!(report.plans >= 4, "two d=2 chains");
    let bytes = artifact::write_bundle(&tuned);
    let ids: Vec<u32> = artifact::list_sections(&bytes).unwrap().iter().map(|s| s.id).collect();
    assert!(ids.contains(&SEC_TUNE), "{ids:?}");
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(back, tuned);
    for op in &back.ops {
        if let BundleOp::Tt(t) = op {
            let plans = t.tuned.as_ref().expect("tuned plans persisted");
            for (tp, ap) in plans.iter().zip(&t.plans) {
                assert_eq!(tp.dims, ap.dims);
                assert_eq!(tp.vector_loop, ap.vector_loop);
                assert_eq!(tp.pack_g, ap.pack_g);
            }
        }
    }
}

#[test]
fn tuned_and_analytic_engines_serve_bitwise_identically() {
    force_scalar();
    // the acceptance pin: persisted measured plans change performance
    // only, never a single output bit
    let analytic = lenet_bundle();
    let mut tuned = analytic.clone();
    artifact::tune_bundle(&mut tuned, &k1(), &MeasureFloor::quick()).unwrap();
    let tuned = artifact::read_bundle_bytes(&artifact::write_bundle(&tuned)).unwrap();
    let mut e_analytic = analytic.build_engine(&k1()).unwrap();
    let mut e_tuned = tuned.build_engine(&k1()).unwrap();
    let mut rng = Rng::new(17);
    for batch in [1usize, 4] {
        let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
        let a = e_analytic.forward(&x).unwrap();
        let b = e_tuned.forward(&x).unwrap();
        assert_bitwise_eq(&b, &a, &format!("tuned vs analytic, batch {batch}"));
    }
}

#[test]
fn verify_passes_on_a_tuned_bundle() {
    force_scalar();
    // tuned plans are measured (non-reproducible), so verify compares
    // bytes with the TUNE section stripped — and replays the tuned engine
    // bitwise against the analytic fresh compression
    let mut tuned = lenet_bundle().clone();
    artifact::tune_bundle(&mut tuned, &k1(), &MeasureFloor::quick()).unwrap();
    let back = artifact::read_bundle_bytes(&artifact::write_bundle(&tuned)).unwrap();
    let report = artifact::verify(&back, &k1(), &DseConfig::default()).unwrap();
    assert_eq!(report.tt_layers, 2);
}

#[test]
fn server_from_artifact_serves_persisted_tuned_plans_bitwise() {
    force_scalar();
    // compress --tune -> serve-demo --artifact, as a library-level e2e
    let mut tuned = lenet_bundle().clone();
    artifact::tune_bundle(&mut tuned, &k1(), &MeasureFloor::quick()).unwrap();
    let path = std::env::temp_dir().join(format!(
        "ttrv_artifact_suite_tuned_{}.ttrv",
        std::process::id()
    ));
    artifact::write_bundle_file(&path, &tuned).unwrap();
    let server =
        Server::from_artifact(&path, &k1(), ttrv::config::ServeConfig::default()).unwrap();
    let mut reference = lenet_bundle().build_engine(&k1()).unwrap(); // analytic
    let mut rng = Rng::new(23);
    for id in 0..8u64 {
        let input = rng.normal_vec(784, 1.0);
        let resp = server
            .infer(InferenceRequest::new(id, input.clone()))
            .unwrap();
        let x = Tensor::from_vec(vec![1, 784], input).unwrap();
        let want = reference.forward(&x).unwrap();
        for (a, b) in resp.output.iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "tuned serving drifted");
        }
    }
    server.shutdown();
    std::fs::remove_file(&path).unwrap();
}

fn assert_tune_corruption_rejected(bytes: &[u8], what: &str, f: impl FnOnce(&mut Vec<u8>)) {
    let corrupt = with_patched_tune(bytes, f);
    let err = artifact::read_bundle_bytes(&corrupt).expect_err(&format!("{what} accepted"));
    assert!(matches!(err, Error::Artifact(_)), "{what}: {err}");
    assert!(err.to_string().contains("TUNE"), "{what}: {err}");
}

#[test]
fn corrupted_tune_sections_are_typed_errors() {
    let bundle = tuned_single_layer_bundle();
    let bytes = artifact::write_bundle(&bundle);
    // sanity: the untouched container decodes
    assert_eq!(artifact::read_bundle_bytes(&bytes).unwrap(), bundle);

    // TUNE payload layout: count u32 | idx u32 | plan_count u32 | plans
    // (plan: kind u8 at +0, dims 5 x u64 at +1, pack_g u8 at +41,
    //  vloop u8 at +42, ... — first plan starts at payload byte 12)
    assert_tune_corruption_rejected(&bytes, "truncated", |p| {
        p.pop();
    });
    assert_tune_corruption_rejected(&bytes, "trailing bytes", |p| p.push(0xAB));
    assert_tune_corruption_rejected(&bytes, "op index out of range", |p| {
        p[4..8].copy_from_slice(&9u32.to_le_bytes())
    });
    assert_tune_corruption_rejected(&bytes, "wrong plan count", |p| {
        p[8..12].copy_from_slice(&1u32.to_le_bytes())
    });
    assert_tune_corruption_rejected(&bytes, "entry count too large", |p| {
        p[0..4].copy_from_slice(&5u32.to_le_bytes())
    });
    assert_tune_corruption_rejected(&bytes, "plan dims drifted", |p| p[13] ^= 0x01);
    assert_tune_corruption_rejected(&bytes, "vector loop changed", |p| p[54] = (p[54] + 1) % 3);
}

#[test]
fn id_4_is_tune_only_from_version_2() {
    // a version-1 file carrying an id-4 section predates the TUNE
    // grammar: it is an unknown (possibly third-party) section and must
    // be skipped, exactly as the v1 reader skipped it — while the same
    // bytes under a v2 header must be grammar-validated and rejected
    let bundle = lenet_bundle();
    let ids_and_payloads: Vec<(u32, Vec<u8>)> = {
        let bytes = artifact::write_bundle(bundle);
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        (0..count)
            .map(|i| {
                let e =
                    &bytes[HEADER_LEN + i * TOC_ENTRY_LEN..HEADER_LEN + (i + 1) * TOC_ENTRY_LEN];
                let id = u32::from_le_bytes(e[0..4].try_into().unwrap());
                let off = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
                let len = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
                (id, bytes[off..off + len].to_vec())
            })
            .chain(std::iter::once((SEC_TUNE, b"not a TUNE section".to_vec())))
            .collect()
    };
    let mut bytes = container(&ids_and_payloads); // stamped FORMAT_VERSION (2)
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("TUNE"), "{err}");
    bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(&back, bundle, "v1 id-4 section must be skipped, not decoded");
}

#[test]
fn pre_bump_version_1_bundle_still_loads() {
    // additive forward-compat: the writer stamps v2, but a v1 container
    // with the same sections must decode identically (the golden bundle
    // pins the on-disk case; this pins the header rule itself)
    let bundle = lenet_bundle();
    let mut bytes = artifact::write_bundle(bundle);
    assert_eq!(
        u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
        artifact::FORMAT_VERSION
    );
    bytes[4..8].copy_from_slice(&artifact::MIN_FORMAT_VERSION.to_le_bytes());
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(&back, bundle);
    // ...and a version below the supported range is still rejected
    bytes[4..8].copy_from_slice(&0u32.to_le_bytes());
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("version"), "{err}");
}

// ---------------------------------------------------------------------------
// QUANT section (format v4: int8-quantized TT cores)
// ---------------------------------------------------------------------------

use ttrv::artifact::format::SEC_QUANT;

/// One quantized LeNet300 (no error budget: always applies), shared across
/// the QUANT tests. The measured error it records is kernel-independent
/// (`measured_quant_error` pins the portable reference kernels itself),
/// but the fixture raises force-scalar anyway — suite policy: anything
/// that executes engines runs forced-scalar.
fn quantized_lenet_bundle() -> &'static ModelBundle {
    static BUNDLE: OnceLock<ModelBundle> = OnceLock::new();
    BUNDLE.get_or_init(|| {
        force_scalar();
        let mut bundle = lenet_bundle().clone();
        let report = artifact::quantize_bundle(&mut bundle, &k1(), None).unwrap();
        assert!(report.applied);
        bundle
    })
}

/// Rebuild a written bundle's container with its QUANT payload transformed
/// (CRCs fixed up), mirroring [`with_patched_tune`].
fn with_patched_quant(bytes: &[u8], f: impl FnOnce(&mut Vec<u8>)) -> Vec<u8> {
    let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let mut sections = Vec::with_capacity(count);
    for i in 0..count {
        let e = &bytes[HEADER_LEN + i * TOC_ENTRY_LEN..HEADER_LEN + (i + 1) * TOC_ENTRY_LEN];
        let id = u32::from_le_bytes(e[0..4].try_into().unwrap());
        let off = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
        let mut payload = bytes[off..off + len].to_vec();
        if id == SEC_QUANT {
            f(&mut payload);
        }
        sections.push((id, payload));
    }
    container(&sections)
}

#[test]
fn quant_section_roundtrips_and_is_optional() {
    force_scalar();
    // without quantized cores: no QUANT section in the container
    let bytes = artifact::write_bundle(lenet_bundle());
    let ids: Vec<u32> = artifact::list_sections(&bytes).unwrap().iter().map(|s| s.id).collect();
    assert!(!ids.contains(&SEC_QUANT), "{ids:?}");

    // with int8 cores: the section appears and round-trips exactly,
    // shrinking the resident TT core bytes by at least 3.5x (the int8
    // payload is 1/4 of f32; scales and the pad-lane layout cost the rest)
    let quantized = quantized_lenet_bundle();
    let bytes = artifact::write_bundle(quantized);
    let ids: Vec<u32> = artifact::list_sections(&bytes).unwrap().iter().map(|s| s.id).collect();
    assert!(ids.contains(&SEC_QUANT), "{ids:?}");
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(&back, quantized);
    let (mut f32_bytes, mut int8_bytes) = (0u64, 0u64);
    for op in &back.ops {
        if let BundleOp::Tt(t) = op {
            let q = t.quant.as_ref().expect("int8 cores persisted");
            assert_eq!(q.len(), t.packed.len());
            for (qg, pg) in q.iter().zip(&t.packed) {
                assert_eq!(qg.layout, pg.layout);
                assert_eq!(qg.dims.2, qg.scales.len(), "one scale per m slice");
                f32_bytes += pg.bytes() as u64;
                int8_bytes += qg.bytes() as u64;
            }
        }
    }
    assert!(
        f32_bytes as f64 >= 3.5 * int8_bytes as f64,
        "core bytes only shrank {f32_bytes} -> {int8_bytes}"
    );
}

#[test]
fn quantized_engine_serves_within_the_measured_error_regime() {
    force_scalar();
    // an engine built from a quantized bundle serves the int8 cores; its
    // outputs track the f32 engine within the per-slice quantization
    // error regime (the exact budget is measured and pinned by
    // `quantize_bundle`'s own tests — this is the serving-path e2e)
    let back =
        artifact::read_bundle_bytes(&artifact::write_bundle(quantized_lenet_bundle())).unwrap();
    let mut int8_engine = back.build_engine(&k1()).unwrap();
    let mut f32_engine = lenet_bundle().build_engine(&k1()).unwrap();
    let mut rng = Rng::new(41);
    for batch in [1usize, 4] {
        let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
        let q = int8_engine.forward(&x).unwrap();
        let f = f32_engine.forward(&x).unwrap();
        assert_eq!(q.dims(), f.dims());
        let scale = f.data().iter().fold(0f32, |a, v| a.max(v.abs())).max(1e-6);
        for (i, (a, b)) in q.data().iter().zip(f.data()).enumerate() {
            assert!(
                (a - b).abs() <= 0.1 * scale,
                "batch {batch} elem {i}: int8 {a} vs f32 {b} (scale {scale})"
            );
        }
    }
}

#[test]
fn verify_passes_on_a_quantized_bundle() {
    force_scalar();
    // quantization is deterministic, so verify re-derives the int8 cores
    // from a fresh compression and byte-compares the QUANT section like
    // any other
    let back =
        artifact::read_bundle_bytes(&artifact::write_bundle(quantized_lenet_bundle())).unwrap();
    let report = artifact::verify(&back, &k1(), &DseConfig::default()).unwrap();
    assert_eq!(report.fc_layers, 3);
    assert_eq!(report.tt_layers, 2);
}

fn assert_quant_corruption_rejected(bytes: &[u8], what: &str, f: impl FnOnce(&mut Vec<u8>)) {
    let corrupt = with_patched_quant(bytes, f);
    let err = artifact::read_bundle_bytes(&corrupt).expect_err(&format!("{what} accepted"));
    assert!(matches!(err, Error::Artifact(_)), "{what}: {err}");
    assert!(err.to_string().contains("QUANT"), "{what}: {err}");
}

#[test]
fn corrupted_quant_sections_are_typed_errors() {
    let bytes = artifact::write_bundle(quantized_lenet_bundle());
    // sanity: the untouched container decodes
    assert_eq!(&artifact::read_bundle_bytes(&bytes).unwrap(), quantized_lenet_bundle());

    // QUANT payload layout: count u32 | idx u32 | steps u32 | cores
    // (core: layout u8 at +0, r/n/m/k/r_pad 5 x u64 at +1, scale count +
    // scales, data len + raw int8 — first core starts at payload byte 12)
    assert_quant_corruption_rejected(&bytes, "truncated", |p| {
        p.pop();
    });
    assert_quant_corruption_rejected(&bytes, "trailing bytes", |p| p.push(0xAB));
    assert_quant_corruption_rejected(&bytes, "op index out of range", |p| {
        p[4..8].copy_from_slice(&9u32.to_le_bytes())
    });
    assert_quant_corruption_rejected(&bytes, "wrong core count", |p| {
        p[8..12].copy_from_slice(&1u32.to_le_bytes())
    });
    assert_quant_corruption_rejected(&bytes, "entry count too large", |p| {
        p[0..4].copy_from_slice(&9u32.to_le_bytes())
    });
    assert_quant_corruption_rejected(&bytes, "unknown layout tag", |p| p[12] = 0xFF);
    assert_quant_corruption_rejected(&bytes, "dims disagree with OPS core", |p| p[13] ^= 0x01);
}

#[test]
fn id_5_is_quant_only_from_version_4() {
    // a pre-v4 file carrying an id-5 section predates the QUANT grammar:
    // it is an unknown section and must be skipped — while the same bytes
    // under a v4 header must be grammar-validated and rejected
    let bundle = lenet_bundle();
    let ids_and_payloads: Vec<(u32, Vec<u8>)> = {
        let bytes = artifact::write_bundle(bundle);
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        (0..count)
            .map(|i| {
                let e =
                    &bytes[HEADER_LEN + i * TOC_ENTRY_LEN..HEADER_LEN + (i + 1) * TOC_ENTRY_LEN];
                let id = u32::from_le_bytes(e[0..4].try_into().unwrap());
                let off = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
                let len = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
                (id, bytes[off..off + len].to_vec())
            })
            .chain(std::iter::once((SEC_QUANT, b"not a QUANT section".to_vec())))
            .collect()
    };
    let mut bytes = container(&ids_and_payloads); // stamped FORMAT_VERSION (4)
    let err = artifact::read_bundle_bytes(&bytes).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("QUANT"), "{err}");
    bytes[4..8].copy_from_slice(&3u32.to_le_bytes());
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(&back, bundle, "pre-v4 id-5 section must be skipped, not decoded");
}

// ---------------------------------------------------------------------------
// Auto-rank META record (accuracy-budget compression)
// ---------------------------------------------------------------------------

use ttrv::artifact::{AutoRankInfo, AutoRankLayer};

#[test]
fn auto_rank_meta_roundtrips_and_is_optional() {
    // fixed-rank bundles carry no auto keys and stay byte-identical
    let plain = lenet_bundle();
    assert!(plain.auto.is_none());
    let plain_bytes = artifact::write_bundle(plain);

    // the auto record survives write -> read exactly (budget, per-layer
    // picks, dense Nones) — and changes only the META section
    let mut auto = plain.clone();
    auto.auto = Some(AutoRankInfo {
        budget: 0.1,
        layers: vec![
            Some(AutoRankLayer { rank: 4, rel_error: 0.0625 }),
            Some(AutoRankLayer { rank: 2, rel_error: 0.03125 }),
            None,
        ],
    });
    let bytes = artifact::write_bundle(&auto);
    assert_ne!(bytes, plain_bytes);
    let back = artifact::read_bundle_bytes(&bytes).unwrap();
    assert_eq!(back, auto);
    assert_eq!(back.auto.as_ref().unwrap().layers.len(), 3);
}

#[test]
fn auto_rank_meta_corruption_is_a_typed_error() {
    // an auto_layers list that does not cover every FC layer is corrupt
    let mut short = lenet_bundle().clone();
    short.auto = Some(AutoRankInfo {
        budget: 0.1,
        layers: vec![Some(AutoRankLayer { rank: 4, rel_error: 0.1 })], // 1 of 3
    });
    let err = artifact::read_bundle_bytes(&artifact::write_bundle(&short)).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("auto_layers"), "{err}");

    // a non-finite budget never decodes
    let mut bad = lenet_bundle().clone();
    bad.auto = Some(AutoRankInfo { budget: f64::NAN, layers: vec![None, None, None] });
    let err = artifact::read_bundle_bytes(&artifact::write_bundle(&bad)).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("auto_budget"), "{err}");
}

// ---------------------------------------------------------------------------
// Golden artifact (forward-compat tripwire)
// ---------------------------------------------------------------------------

/// Expected outputs of the pinned golden bundle for the pinned input —
/// integer-exact in f32, so they are independent of summation order and
/// hold bit-for-bit on any compliant kernel. Regenerate (only on a
/// deliberate format change, with a version bump) via
/// `python3 python/tools/make_golden_ttrv.py`.
const GOLDEN_EXPECTED: [f32; 10] = [
    -13.0, 98.0, 57.0, -45.0, 177.0, -114.0, -194.0, 11.0, 69.0, -60.0,
];

#[test]
fn golden_artifact_loads_and_serves_pinned_output() {
    force_scalar();
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data/lenet300.ttrv");
    let bundle = artifact::read_bundle_file(&path).unwrap();
    assert_eq!(bundle.name, "lenet300-golden");
    assert_eq!(bundle.machine, "SpacemiT-K1");
    assert_eq!(bundle.shapes, vec![(784, 300), (300, 100), (100, 10)]);
    assert_eq!(bundle.tt_layers(), 2);
    let mut engine = bundle.build_engine(&k1()).unwrap();
    // pinned input: x[i] = ((i * 37) % 7) - 3
    let x = Tensor::from_vec(
        vec![1, 784],
        (0..784).map(|i| ((i * 37) % 7) as f32 - 3.0).collect(),
    )
    .unwrap();
    let y = engine.forward(&x).unwrap();
    assert_eq!(y.dims(), &[1, 10]);
    for (i, (got, want)) in y.data().iter().zip(&GOLDEN_EXPECTED).enumerate() {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "golden output {i}: got {got}, pinned {want} — if this is a deliberate \
             format/kernel change, bump FORMAT_VERSION and regenerate the golden bundle"
        );
    }
}

// ---------------------------------------------------------------------------
// End-to-end: compress -> file -> Server::from_artifact
// ---------------------------------------------------------------------------

#[test]
fn server_from_artifact_serves_bitwise_identical_responses() {
    force_scalar();
    let bundle = lenet_bundle();
    let path = std::env::temp_dir().join(format!(
        "ttrv_artifact_suite_{}.ttrv",
        std::process::id()
    ));
    artifact::write_bundle_file(&path, bundle).unwrap();

    let cfg = ttrv::config::ServeConfig { workers: 2, ..Default::default() };
    let server = Server::from_artifact(&path, &k1(), cfg).unwrap();
    let mut reference = bundle.build_engine(&k1()).unwrap();
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f32>> = (0..32).map(|_| rng.normal_vec(784, 1.0)).collect();
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(id, input)| {
            server
                .submit(InferenceRequest::new(id as u64, input.clone()))
                .unwrap()
        })
        .collect();
    for (input, rx) in inputs.iter().zip(rxs) {
        let resp = rx.recv().unwrap().unwrap();
        // responses are row-invariant to batching, so batch-1 reference
        // rows must match bitwise (same invariant the pool tests pin)
        let x = Tensor::from_vec(vec![1, 784], input.clone()).unwrap();
        let want = reference.forward(&x).unwrap();
        assert_eq!(resp.output.len(), 10);
        for (a, b) in resp.output.iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "served response drifted");
        }
    }
    server.shutdown();
    // a corrupted file refuses to serve, loudly
    let mut corrupt = artifact::write_bundle(bundle);
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x01;
    std::fs::write(&path, &corrupt).unwrap();
    match Server::from_artifact(&path, &k1(), ttrv::config::ServeConfig::default()) {
        Err(e) => assert!(matches!(e, Error::Artifact(_)), "{e}"),
        Ok(_) => panic!("corrupted bundle must not serve"),
    }
    std::fs::remove_file(&path).unwrap();
}
