//! Integration: the full Table-3 CB suite, every optimization stage, every
//! kernel variant, against the reference einsum — plus randomized sweeps.
//! Everything runs through the one [`Executor`] entry point.

use ttrv::compiler::cb_suite;
use ttrv::compiler::pipeline::{compile_stage, OptStage};
use ttrv::kernels::{pack, Executor};
use ttrv::machine::MachineSpec;
use ttrv::tensor::einsum::tt_einsum_ref;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};
use ttrv::util::prng::Rng;

fn check_dims(dims: &EinsumDims, machine: &MachineSpec, rng: &mut Rng, stage: OptStage) {
    let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, rng);
    let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, rng);
    let want = tt_einsum_ref(&g, &x).unwrap();
    let plan = compile_stage(dims, machine, stage).unwrap();
    let pg = pack(&g, &plan).unwrap();
    let mut ex = Executor::new(machine);
    ex.set_plan(plan).unwrap();
    let got = ex.execute(dims, &pg, &x).unwrap();
    // accumulation-order noise grows with the contraction length (reference
    // sums sequentially, microkernels pairwise across lanes)
    let tol = 2e-4 * ((dims.n * dims.k) as f32).sqrt().max(1.0);
    assert!(
        got.allclose(&want, tol, tol),
        "{dims:?} at {stage:?}: maxdiff {} (tol {tol})",
        got.max_abs_diff(&want).unwrap()
    );
}

#[test]
fn full_cb_suite_all_variants_full_pipeline() {
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(1);
    for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
        for e in cb_suite(kind) {
            // bound the largest b to keep runtime sane; shape structure and
            // remainder handling is what matters for correctness
            let mut dims = e.dims;
            dims.b = dims.b.min(512);
            check_dims(&dims, &machine, &mut rng, OptStage::Parallel);
        }
    }
}

#[test]
fn ablation_stages_on_selected_cbs() {
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(2);
    for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
        for e in cb_suite(kind).into_iter().step_by(3) {
            let mut dims = e.dims;
            dims.b = dims.b.min(128);
            for stage in [OptStage::Naive, OptStage::VecPack, OptStage::RbTile] {
                check_dims(&dims, &machine, &mut rng, stage);
            }
        }
    }
}

#[test]
fn host_machine_plans_also_correct() {
    // plans for the host spec (16 vregs, 1 core) must execute correctly too
    let machine = MachineSpec::host();
    let mut rng = Rng::new(3);
    for e in cb_suite(EinsumKind::Middle).into_iter().take(4) {
        let mut dims = e.dims;
        dims.b = dims.b.min(256);
        check_dims(&dims, &machine, &mut rng, OptStage::Parallel);
    }
}

#[test]
fn randomized_shape_fuzz() {
    let machine = MachineSpec::spacemit_k1();
    ttrv::testkit::check("integration kernel fuzz", 60, |d| {
        let m = d.usize_in(1, 96);
        let b = d.usize_in(1, 96);
        let n = d.usize_in(1, 20);
        let (r, k) = *d.choose(&[
            (8usize, 8usize),
            (8, 1),
            (1, 8),
            (16, 16),
            (24, 8),
            (8, 24),
            (1, 1),
            (2, 2),
        ]);
        let kind = if k == 1 && r > 1 {
            EinsumKind::First
        } else if r == 1 {
            EinsumKind::Final
        } else {
            EinsumKind::Middle
        };
        let dims = EinsumDims { kind, m, b, n, r, k };
        let mut rng = d.rng().fork();
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let want = tt_einsum_ref(&g, &x).map_err(|e| e.to_string())?;
        let mut ex = Executor::new(&machine);
        let pg = ex.pack(&g, &dims).map_err(|e| e.to_string())?;
        let got = ex.execute(&dims, &pg, &x).map_err(|e| e.to_string())?;
        if got.allclose(&want, 1e-3, 1e-3) {
            Ok(())
        } else {
            Err(format!("{dims:?}: {}", got.max_abs_diff(&want).unwrap()))
        }
    });
}

#[test]
fn baselines_agree_with_kernel_engine() {
    // ours, IREE-like and Pluto-like must all compute the same function —
    // and all three run through the Executor entry point
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(4);
    let mut ex = Executor::new(&machine);
    for e in cb_suite(EinsumKind::Middle).into_iter().take(5) {
        let mut dims = e.dims;
        dims.b = dims.b.min(200);
        let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, &mut rng);
        let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, &mut rng);
        let pg = ex.pack(&g, &dims).unwrap();
        let ours = ex.execute(&dims, &pg, &x).unwrap();
        let iree = ex.execute_iree_like(&g, &x).unwrap();
        let pluto = ex.execute_pluto_like(&g, &x).unwrap();
        assert!(ours.allclose(&iree, 2e-4, 2e-4), "{}", e.id);
        assert!(ours.allclose(&pluto, 2e-4, 2e-4), "{}", e.id);
    }
}
