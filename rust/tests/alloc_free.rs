//! Proof that the serving hot loop performs zero heap allocation per request
//! on all three `G` layouts (Canonical, PackedR, PackedK), via a counting
//! global allocator. Plans are pinned to one thread — the serving hot-loop
//! configuration — because the multi-threaded paths inherently allocate
//! their fork/join scratch (per-thread slices / merge buffers).
//! Everything lives in ONE #[test] so concurrent tests cannot perturb the
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use ttrv::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
use ttrv::kernels::{pack, Executor, VL};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{einsum_chain, EinsumDims, EinsumKind};
use ttrv::ttd::decompose::random_cores;
use ttrv::ttd::TtLayout;
use ttrv::util::prng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

fn single_thread_plan(dims: EinsumDims, pack_g: bool, vloop: VectorLoop) -> OptimizationPlan {
    OptimizationPlan {
        dims,
        pack_g,
        vector_loop: vloop,
        vl: if vloop == VectorLoop::None { 1 } else { VL },
        rb: RbFactors { rm: 2, rb: 3, rr: 1, rk: 1 },
        tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
        threads: 1,
        ls_estimate: 0,
    }
}

#[test]
fn hot_loop_is_allocation_free_on_all_layouts() {
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(120);
    let dims = EinsumDims { kind: EinsumKind::Middle, m: 24, b: 17, n: 5, r: 8, k: 8 };
    let g = Tensor::randn(vec![8, 5, 24, 8], 1.0, &mut rng);
    let x = Tensor::randn(vec![17, 5, 8], 1.0, &mut rng);

    // single-kernel hot path: each of the three layouts must be
    // allocation-free after the first (warming) call
    let cases = [
        ("Canonical", single_thread_plan(dims, false, VectorLoop::None)),
        ("PackedR", single_thread_plan(dims, true, VectorLoop::R)),
        ("PackedK", single_thread_plan(dims, true, VectorLoop::None)),
    ];
    for (name, plan) in cases {
        let mut ex = Executor::new(&machine);
        ex.set_plan(plan).unwrap();
        let pg = pack(&g, &plan).unwrap();
        // warm: resizes scratch, no further growth afterwards
        ex.execute_with_scratch(&dims, &pg, x.data()).unwrap();
        ex.execute_with_scratch(&dims, &pg, x.data()).unwrap();
        let before = allocs();
        for _ in 0..10 {
            ex.execute_with_scratch(&dims, &pg, x.data()).unwrap();
        }
        let delta = allocs() - before;
        assert_eq!(delta, 0, "{name}: {delta} allocations in 10 warm executes");
    }

    // chain hot path (the serving engine's forward): warm once per batch,
    // then zero allocations per request
    let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
    let tt = random_cores(&layout, &mut rng);
    let mut ex = Executor::new(&machine);
    let chain = einsum_chain(&layout, 4);
    // force single-thread plans so no scoped-thread spawns allocate
    let packed: Vec<_> = chain
        .iter()
        .enumerate()
        .map(|(step, d)| {
            let mut plan = ex.plan(d).unwrap();
            plan.threads = 1;
            ex.set_plan(plan).unwrap();
            ex.pack(&tt.cores[layout.d() - 1 - step], d).unwrap()
        })
        .collect();
    let xb = Tensor::randn(vec![4, 180], 1.0, &mut rng);
    ex.run_tt_chain(&layout, 4, &packed, xb.data()).unwrap();
    ex.run_tt_chain(&layout, 4, &packed, xb.data()).unwrap();
    let before = allocs();
    for _ in 0..10 {
        ex.run_tt_chain(&layout, 4, &packed, xb.data()).unwrap();
    }
    let delta = allocs() - before;
    assert_eq!(delta, 0, "chain: {delta} allocations in 10 warm requests");
}
