//! Integration: the serving coordinator over real TT-compressed models —
//! single worker, pools, sharded queues, and multi-model co-hosting.
//!
//! The load-bearing invariant pinned here is bitwise response stability:
//! the same request stream must produce byte-identical outputs no matter
//! how many workers serve it, how many queue shards it crosses, whether
//! work stealing fired, or how many other models share the process.
//!
//! Tier-1 bitwise pin: every test runs forced-scalar (portable kernel) so
//! those byte-identity assertions hold on hosts with SIMD kernels too —
//! vector kernels move low-order FMA bits and are verified by the
//! tolerance suite in `kernel_reference.rs` instead.

use std::time::Instant;

use ttrv::baselines::dense::DenseFc;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{
    InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine,
};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// Build a DSE-routed TT LeNet300 and an equivalent dense model (same
/// reconstructed weights) for output comparison.
fn build_pair(rng: &mut Rng) -> (ModelEngine, ModelEngine) {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut tt_ops = Vec::new();
    let mut dense_ops = Vec::new();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg).unwrap() {
            Route::Tt(sol) => {
                let tt = random_cores(sol.layout(), rng);
                let w = tt.reconstruct().unwrap();
                tt_ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.1, rng);
                tt_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
        }
        if i + 1 < shapes.len() {
            tt_ops.push(LayerOp::Relu);
            dense_ops.push(LayerOp::Relu);
        }
    }
    (
        ModelEngine::new("lenet300-tt", tt_ops, 784, 10),
        ModelEngine::new("lenet300-dense", dense_ops, 784, 10),
    )
}

/// DSE-route an arbitrary FC stack into a TT/dense engine with seeded
/// random weights.
fn build_tt(name: &str, shapes: &[(u64, u64)], seed: u64) -> ModelEngine {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut rng = Rng::new(seed);
    let mut ops = Vec::new();
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg).unwrap() {
            Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), &mut rng);
                tt.bias = Some(vec![0.0; m as usize]);
                ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).unwrap()));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
                ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
        }
        if i + 1 < shapes.len() {
            ops.push(LayerOp::Relu);
        }
    }
    let in_dim = shapes[0].0 as usize;
    let out_dim = shapes[shapes.len() - 1].1 as usize;
    ModelEngine::new(name, ops, in_dim, out_dim)
}

fn cfg4(max_batch: usize, max_wait_us: u64, queue_cap: usize, workers: usize) -> ServeConfig {
    ServeConfig { max_batch, max_wait_us, queue_cap, workers, ..ServeConfig::default() }
}

/// Pin this process to the portable reference kernel (first statement of
/// every test here; the flag is global and only ever raised, so the
/// parallel test harness cannot race it off).
fn force_scalar() {
    ttrv::kernels::set_force_scalar(true);
}

#[test]
fn served_outputs_match_dense_reference_model() {
    force_scalar();
    let mut rng = Rng::new(21);
    let (tt_model, mut dense_model) = build_pair(&mut rng);
    let server = Server::start(tt_model, cfg4(8, 200, 128, 1));
    for id in 0..24u64 {
        let input = rng.normal_vec(784, 1.0);
        let resp = server.infer(InferenceRequest::new(id, input.clone())).unwrap();
        let x = Tensor::from_vec(vec![1, 784], input).unwrap();
        let want = dense_model.forward(&x).unwrap();
        for (a, b) in resp.output.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-2 + 1e-2 * b.abs(), "{a} vs {b}");
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests, 24);
    server.shutdown();
}

#[test]
fn concurrent_clients_get_consistent_replies() {
    force_scalar();
    let mut rng = Rng::new(22);
    let (tt_model, _) = build_pair(&mut rng);
    let server = std::sync::Arc::new(Server::start(tt_model, cfg4(16, 300, 512, 1)));
    // a fixed probe input must produce identical output regardless of the
    // batch it rides in
    let probe: Vec<f32> = (0..784).map(|i| (i % 13) as f32 / 13.0).collect();
    let expected = server.infer(InferenceRequest::new(0, probe.clone())).unwrap().output;

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let probe = probe.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..25u64 {
                if i % 3 == 0 {
                    let out = server
                        .infer(InferenceRequest::new(t * 1000 + i, probe.clone()))
                        .unwrap()
                        .output;
                    for (a, b) in out.iter().zip(&expected) {
                        assert!((a - b).abs() < 1e-4, "probe drifted: {a} vs {b}");
                    }
                } else {
                    let input = rng.normal_vec(784, 1.0);
                    server.infer(InferenceRequest::new(t * 1000 + i, input)).unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 1 + 4 * 25);
    assert!(m.mean_batch() >= 1.0);
}

#[test]
fn throughput_improves_with_batching() {
    // serving sanity: under burst load the dynamic batcher forms multi-
    // request batches and every request is answered. Batching is
    // opportunistic (depends on scheduler interleaving on a 1-core host),
    // so the batching assertion is retried across bursts; losing a request
    // is never tolerated.
    force_scalar();
    let mut rng = Rng::new(23);
    let (tt_model, _) = build_pair(&mut rng);
    let server = Server::start(tt_model, cfg4(32, 20_000, 512, 1));
    let mut batched = false;
    for attempt in 0..5 {
        let inputs: Vec<Vec<f32>> = (0..128).map(|_| rng.normal_vec(784, 1.0)).collect();
        let rxs: Vec<_> = inputs
            .into_iter()
            .enumerate()
            .map(|(id, input)| {
                server.submit(InferenceRequest::new((attempt * 1000 + id) as u64, input)).unwrap()
            })
            .collect();
        let mut max_batch = 0usize;
        for rx in rxs {
            max_batch = max_batch.max(rx.recv().unwrap().unwrap().batch_size);
        }
        assert!(max_batch <= 32);
        if max_batch > 1 {
            batched = true;
            break;
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests % 128, 0);
    assert!(batched, "no burst formed a multi-request batch in 5 attempts");
    server.shutdown();
}

/// The two FC stacks of the co-hosting matrix: LeNet300 and the LeNet5 FC
/// tail, (name, shapes, weight seed).
const MATRIX_MODELS: [(&str, &[(u64, u64)], u64); 2] = [
    ("a-tt", &[(784, 300), (300, 100), (100, 10)], 31),
    ("b-tt", &[(400, 120), (120, 84), (84, 10)], 32),
];

/// Serve a fixed per-model request stream on `hosted` co-hosted models
/// with the given pool/shard geometry and return the output bit patterns
/// as `bits[model][request]`. Engines are `worker_clone`s of `protos`, so
/// every call serves identical weights and any cross-call difference can
/// only come from the serving layer.
fn serve_matrix_bits(
    protos: &[ModelEngine],
    hosted: usize,
    workers: usize,
    shards: usize,
    per_model: usize,
) -> Vec<Vec<Vec<u32>>> {
    let engines: Vec<ModelEngine> = protos[..hosted].iter().map(ModelEngine::worker_clone).collect();
    let in_dims: Vec<usize> = (0..hosted).map(|i| MATRIX_MODELS[i].1[0].0 as usize).collect();
    let names: Vec<&str> = (0..hosted).map(|i| MATRIX_MODELS[i].0).collect();
    let server = Server::start_multi(
        engines,
        ServeConfig {
            max_batch: 8,
            max_wait_us: 500,
            queue_cap: 4096,
            workers,
            shards,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    // per-model input streams from fixed seeds, submitted interleaved in
    // one burst so batches form (and form *differently* across geometries
    // — which the outputs must not care about)
    let streams: Vec<Vec<Vec<f32>>> = (0..hosted)
        .map(|mi| {
            let mut rng = Rng::new(77 + mi as u64);
            (0..per_model).map(|_| rng.normal_vec(in_dims[mi], 1.0)).collect()
        })
        .collect();
    let mut rxs: Vec<Vec<_>> = (0..hosted).map(|_| Vec::with_capacity(per_model)).collect();
    for i in 0..per_model {
        for mi in 0..hosted {
            let req = InferenceRequest::new((mi * per_model + i) as u64, streams[mi][i].clone())
                .for_model(names[mi]);
            rxs[mi].push(server.submit(req).unwrap());
        }
    }
    let bits: Vec<Vec<Vec<u32>>> = rxs
        .into_iter()
        .map(|model_rxs| {
            model_rxs
                .into_iter()
                .map(|rx| {
                    let resp = rx.recv().unwrap().unwrap();
                    resp.output.iter().map(|v| v.to_bits()).collect()
                })
                .collect()
        })
        .collect();
    let m = server.metrics();
    assert_eq!(m.requests, (hosted * per_model) as u64);
    server.shutdown();
    bits
}

#[test]
fn responses_bitwise_stable_across_shards_workers_and_cohosting() {
    // Serving-v2 acceptance: the response for a given (model, input) is one
    // bit pattern, full stop — across every combination of queue shards,
    // worker counts, steal schedules (implied by shards < workers and
    // timing), and co-hosted neighbors. Reference: each model served alone
    // on the minimal geometry.
    force_scalar();
    let protos: Vec<ModelEngine> =
        MATRIX_MODELS.iter().map(|&(n, s, seed)| build_tt(n, s, seed)).collect();
    let per_model = 48;
    let reference = [
        serve_matrix_bits(&protos, 1, 1, 1, per_model).remove(0),
        {
            // model B alone: host it as the only model via a reordered view
            let solo_b = Server::start(protos[1].worker_clone(), cfg4(8, 500, 4096, 1));
            let mut rng = Rng::new(78);
            let inputs: Vec<Vec<f32>> =
                (0..per_model).map(|_| rng.normal_vec(400, 1.0)).collect();
            let rxs: Vec<_> = inputs
                .into_iter()
                .enumerate()
                .map(|(id, input)| {
                    solo_b.submit(InferenceRequest::new(id as u64, input)).unwrap()
                })
                .collect();
            let bits: Vec<Vec<u32>> = rxs
                .into_iter()
                .map(|rx| {
                    rx.recv().unwrap().unwrap().output.iter().map(|v| v.to_bits()).collect()
                })
                .collect();
            solo_b.shutdown();
            bits
        },
    ];
    for shards in [1usize, 4] {
        for workers in [1usize, 4] {
            for hosted in [1usize, 2] {
                let got = serve_matrix_bits(&protos, hosted, workers, shards, per_model);
                for (mi, model_bits) in got.iter().enumerate() {
                    assert_eq!(
                        model_bits, &reference[mi],
                        "model {} diverged at shards={shards} workers={workers} hosted={hosted}",
                        MATRIX_MODELS[mi].0
                    );
                }
            }
        }
    }
}

/// A deliberately heavy dense stack: one batch execution takes orders of
/// magnitude longer than a submission, so a burst deterministically
/// saturates a 1-slot queue.
fn slow_engine() -> ModelEngine {
    let mut rng = Rng::new(55);
    let mut ops = Vec::new();
    for i in 0..6 {
        let w = Tensor::randn(vec![512, 512], 0.05, &mut rng);
        ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
        if i < 5 {
            ops.push(LayerOp::Relu);
        }
    }
    ModelEngine::new("slow-dense", ops, 512, 512)
}

#[test]
fn queue_saturation_rejects_instead_of_blocking() {
    // max_batch 1 + queue_cap 1: the server can absorb at most two of a
    // tight burst (one executing, one queued); the rest must be refused
    // immediately via the admission-control error, never by blocking.
    force_scalar();
    let server = Server::start(slow_engine(), cfg4(1, 0, 1, 1));
    let t0 = Instant::now();
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for id in 0..6u64 {
        match server.submit(InferenceRequest::new(id, vec![0.1; 512])) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(
                    matches!(e, ttrv::Error::QueueFull),
                    "unexpected rejection reason: {e}"
                );
                rejected += 1;
            }
        }
    }
    let burst = t0.elapsed();
    assert!(rejected >= 1, "burst never hit admission control");
    // the submit path must have failed fast, not waited for capacity
    assert!(burst.as_secs() < 5, "submissions blocked for {burst:?}");
    // every accepted request is still answered exactly once
    for rx in accepted {
        rx.recv().unwrap().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.rejected, rejected);
    assert_eq!(m.requests + rejected, 6);
    server.shutdown();
}

#[test]
fn pool_serves_concurrent_clients_consistently() {
    // the pool variant of the probe-drift test: four client threads, four
    // workers, a fixed probe input must produce bit-stable output no
    // matter which worker or batch serves it
    force_scalar();
    let mut rng = Rng::new(24);
    let (tt_model, _) = build_pair(&mut rng);
    let server = std::sync::Arc::new(Server::start(tt_model, cfg4(16, 300, 512, 4)));
    assert_eq!(server.workers(), 4);
    let probe: Vec<f32> = (0..784).map(|i| (i % 13) as f32 / 13.0).collect();
    let expected = server.infer(InferenceRequest::new(0, probe.clone())).unwrap().output;

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let probe = probe.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(200 + t);
            for i in 0..25u64 {
                if i % 3 == 0 {
                    let out = server
                        .infer(InferenceRequest::new(t * 1000 + i, probe.clone()))
                        .unwrap()
                        .output;
                    for (a, b) in out.iter().zip(&expected) {
                        assert_eq!(a.to_bits(), b.to_bits(), "probe drifted across workers");
                    }
                } else {
                    let input = rng.normal_vec(784, 1.0);
                    server.infer(InferenceRequest::new(t * 1000 + i, input)).unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 1 + 4 * 25);
    assert!(m.mean_batch() >= 1.0);
}

/// Compress two tiny FC stacks into `.ttrv` files under a fresh temp dir.
fn write_tiny_artifacts(dir: &std::path::Path) -> Vec<std::path::PathBuf> {
    let machine = MachineSpec::spacemit_k1();
    let dse = DseConfig::default();
    std::fs::create_dir_all(dir).unwrap();
    let mut paths = Vec::new();
    for (name, shapes, seed) in
        [("tiny-a", vec![(64u64, 32u64)], 7u64), ("tiny-b", vec![(48, 24)], 9)]
    {
        let spec = ttrv::artifact::CompressSpec { name: name.to_string(), shapes, rank: 4, seed };
        let bundle = ttrv::artifact::compress(&spec, &machine, &dse).unwrap();
        let path = dir.join(format!("{name}.ttrv"));
        ttrv::artifact::write_bundle_file(&path, &bundle).unwrap();
        paths.push(path);
    }
    paths
}

#[test]
fn artifact_eviction_and_reload_keep_outputs_bitwise_stable() {
    // Satellite 3 at the integration level: co-host two .ttrv bundles under
    // a cache budget smaller than either engine (cache_bytes = 1), so every
    // model switch evicts the other and reloads from the artifact. The
    // interleaved traffic must (a) never deadlock, and (b) produce the same
    // bits for a fixed probe before and after arbitrarily many
    // evict-reload cycles.
    force_scalar();
    let dir = std::env::temp_dir().join(format!("ttrv_serve_evict_{}", std::process::id()));
    let paths = write_tiny_artifacts(&dir);
    let machine = MachineSpec::spacemit_k1();
    let server = Server::from_artifacts(
        &paths,
        &machine,
        ServeConfig {
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 1024,
            workers: 2,
            cache_bytes: 1,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let infos = server.registry().models();
    assert_eq!(infos.len(), 2);
    assert!(!infos[0].pinned, "artifact-backed models must be evictable");

    let probes: Vec<Vec<f32>> = infos.iter().map(|i| vec![0.3; i.in_dim]).collect();
    let expected: Vec<Vec<u32>> = infos
        .iter()
        .zip(&probes)
        .map(|(info, probe)| {
            let resp = server
                .infer(InferenceRequest::new(0, probe.clone()).for_model(info.id.clone()))
                .unwrap();
            resp.output.iter().map(|v| v.to_bits()).collect()
        })
        .collect();

    // interleaved two-model burst: forces A/B/A/B lease alternation under
    // the 1-byte budget on both workers
    let rxs: Vec<_> = (0..60u64)
        .map(|id| {
            let mi = (id % 2) as usize;
            let req = InferenceRequest::new(id, probes[mi].clone())
                .for_model(infos[mi].id.clone());
            server.submit(req).unwrap()
        })
        .collect();
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        let mi = id % 2;
        let bits: Vec<u32> = resp.output.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, expected[mi], "request {id}: output moved across an evict-reload");
    }
    assert!(
        server.registry().evictions() > 0,
        "a 1-byte budget with two models must have evicted at least once"
    );
    assert!(server.registry().loads() > 2, "reloads after eviction should re-count as loads");
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn snapshot_reflects_cohosted_models_and_traffic() {
    // the machine-readable snapshot is the ops surface of serving v2: it
    // must name every co-hosted model and carry the per-model counters that
    // metrics_for() reports
    force_scalar();
    let protos: Vec<ModelEngine> =
        MATRIX_MODELS.iter().map(|&(n, s, seed)| build_tt(n, s, seed)).collect();
    let server = Server::start_multi(
        protos.iter().map(ModelEngine::worker_clone).collect(),
        cfg4(4, 200, 256, 2),
    )
    .unwrap();
    for id in 0..10u64 {
        let mi = (id % 2) as usize;
        let input = vec![0.1; MATRIX_MODELS[mi].1[0].0 as usize];
        server
            .infer(InferenceRequest::new(id, input).for_model(MATRIX_MODELS[mi].0))
            .unwrap();
    }
    let snap = server.snapshot();
    assert_eq!(snap.get("schema").unwrap().as_str(), Some("ttrv-serve-snapshot"));
    let models = snap.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2);
    let mut seen_requests = 0;
    for row in models {
        let name = row.get("model").unwrap().as_str().unwrap();
        assert!(MATRIX_MODELS.iter().any(|&(n, ..)| n == name), "unknown model {name}");
        seen_requests += row
            .get("metrics")
            .unwrap()
            .get("requests")
            .unwrap()
            .as_u64()
            .unwrap();
    }
    assert_eq!(seen_requests, 10);
    server.shutdown();
}
