//! Integration: the serving coordinator over a real TT-compressed model,
//! single worker and pool.

use std::time::Instant;

use ttrv::baselines::dense::DenseFc;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{
    InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine,
};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// Build a DSE-routed TT LeNet300 and an equivalent dense model (same
/// reconstructed weights) for output comparison.
fn build_pair(rng: &mut Rng) -> (ModelEngine, ModelEngine) {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut tt_ops = Vec::new();
    let mut dense_ops = Vec::new();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg).unwrap() {
            Route::Tt(sol) => {
                let tt = random_cores(sol.layout(), rng);
                let w = tt.reconstruct().unwrap();
                tt_ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.1, rng);
                tt_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
        }
        if i + 1 < shapes.len() {
            tt_ops.push(LayerOp::Relu);
            dense_ops.push(LayerOp::Relu);
        }
    }
    (
        ModelEngine::new("lenet300-tt", tt_ops, 784, 10),
        ModelEngine::new("lenet300-dense", dense_ops, 784, 10),
    )
}

#[test]
fn served_outputs_match_dense_reference_model() {
    let mut rng = Rng::new(21);
    let (tt_model, mut dense_model) = build_pair(&mut rng);
    let server = Server::start(
        tt_model,
        ServeConfig { max_batch: 8, max_wait_us: 200, queue_cap: 128, workers: 1 },
    );
    for id in 0..24u64 {
        let input = rng.normal_vec(784, 1.0);
        let resp = server
            .infer(InferenceRequest { id, input: input.clone() })
            .unwrap();
        let x = Tensor::from_vec(vec![1, 784], input).unwrap();
        let want = dense_model.forward(&x).unwrap();
        for (a, b) in resp.output.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-2 + 1e-2 * b.abs(), "{a} vs {b}");
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests, 24);
    server.shutdown();
}

#[test]
fn concurrent_clients_get_consistent_replies() {
    let mut rng = Rng::new(22);
    let (tt_model, _) = build_pair(&mut rng);
    let server = std::sync::Arc::new(Server::start(
        tt_model,
        ServeConfig { max_batch: 16, max_wait_us: 300, queue_cap: 512, workers: 1 },
    ));
    // a fixed probe input must produce identical output regardless of the
    // batch it rides in
    let probe: Vec<f32> = (0..784).map(|i| (i % 13) as f32 / 13.0).collect();
    let expected = server
        .infer(InferenceRequest { id: 0, input: probe.clone() })
        .unwrap()
        .output;

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let probe = probe.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..25u64 {
                if i % 3 == 0 {
                    let out = server
                        .infer(InferenceRequest { id: t * 1000 + i, input: probe.clone() })
                        .unwrap()
                        .output;
                    for (a, b) in out.iter().zip(&expected) {
                        assert!((a - b).abs() < 1e-4, "probe drifted: {a} vs {b}");
                    }
                } else {
                    let input = rng.normal_vec(784, 1.0);
                    server
                        .infer(InferenceRequest { id: t * 1000 + i, input })
                        .unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 1 + 4 * 25);
    assert!(m.mean_batch() >= 1.0);
}

#[test]
fn throughput_improves_with_batching() {
    // serving sanity: under burst load the dynamic batcher forms multi-
    // request batches and every request is answered. Batching is
    // opportunistic (depends on scheduler interleaving on a 1-core host),
    // so the batching assertion is retried across bursts; losing a request
    // is never tolerated.
    let mut rng = Rng::new(23);
    let (tt_model, _) = build_pair(&mut rng);
    let server = Server::start(
        tt_model,
        ServeConfig { max_batch: 32, max_wait_us: 20_000, queue_cap: 512, workers: 1 },
    );
    let mut batched = false;
    for attempt in 0..5 {
        let inputs: Vec<Vec<f32>> = (0..128).map(|_| rng.normal_vec(784, 1.0)).collect();
        let rxs: Vec<_> = inputs
            .into_iter()
            .enumerate()
            .map(|(id, input)| {
                server
                    .submit(InferenceRequest { id: (attempt * 1000 + id) as u64, input })
                    .unwrap()
            })
            .collect();
        let mut max_batch = 0usize;
        for rx in rxs {
            max_batch = max_batch.max(rx.recv().unwrap().unwrap().batch_size);
        }
        assert!(max_batch <= 32);
        if max_batch > 1 {
            batched = true;
            break;
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests % 128, 0);
    assert!(batched, "no burst formed a multi-request batch in 5 attempts");
    server.shutdown();
}

/// Serve a fixed 96-request stream with the given pool size and return the
/// output bit patterns by request id. The model is rebuilt from the same
/// seed each call, so any cross-run difference can only come from the pool.
fn serve_stream_bits(workers: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(31);
    let (tt_model, _) = build_pair(&mut rng);
    let server = Server::start(
        tt_model,
        ServeConfig { max_batch: 8, max_wait_us: 500, queue_cap: 1024, workers },
    );
    let mut input_rng = Rng::new(77);
    let inputs: Vec<Vec<f32>> = (0..96).map(|_| input_rng.normal_vec(784, 1.0)).collect();
    // burst submission so batches actually form (and form *differently*
    // across pool sizes — which the outputs must not care about)
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(id, input)| {
            server
                .submit(InferenceRequest { id: id as u64, input })
                .unwrap()
        })
        .collect();
    let mut bits = vec![Vec::new(); 96];
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, id as u64);
        bits[id] = resp.output.iter().map(|v| v.to_bits()).collect();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 96);
    server.shutdown();
    bits
}

#[test]
fn pool_outputs_byte_identical_to_single_worker() {
    // ISSUE 2 acceptance: workers = 4 must yield byte-identical responses
    // to workers = 1 on the same request stream. This holds because every
    // worker executes the same deterministic plans over the same Arc-shared
    // packed cores, and per-element reduction order is batch-invariant —
    // so neither batch composition nor worker assignment can move a bit.
    let single = serve_stream_bits(1);
    let pool = serve_stream_bits(4);
    for (id, (a, b)) in single.iter().zip(&pool).enumerate() {
        assert!(!a.is_empty(), "request {id} unanswered");
        assert_eq!(a, b, "request {id}: pool output diverged from single worker");
    }
}

/// A deliberately heavy dense stack: one batch execution takes orders of
/// magnitude longer than a submission, so a burst deterministically
/// saturates a 1-slot queue.
fn slow_engine() -> ModelEngine {
    let mut rng = Rng::new(55);
    let mut ops = Vec::new();
    for i in 0..6 {
        let w = Tensor::randn(vec![512, 512], 0.05, &mut rng);
        ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
        if i < 5 {
            ops.push(LayerOp::Relu);
        }
    }
    ModelEngine::new("slow-dense", ops, 512, 512)
}

#[test]
fn queue_saturation_rejects_instead_of_blocking() {
    // max_batch 1 + queue_cap 1: the server can absorb at most two of a
    // tight burst (one executing, one queued); the rest must be refused
    // immediately via the admission-control error, never by blocking.
    let server = Server::start(
        slow_engine(),
        ServeConfig { max_batch: 1, max_wait_us: 0, queue_cap: 1, workers: 1 },
    );
    let t0 = Instant::now();
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for id in 0..6u64 {
        match server.submit(InferenceRequest { id, input: vec![0.1; 512] }) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(
                    matches!(e, ttrv::Error::QueueFull),
                    "unexpected rejection reason: {e}"
                );
                rejected += 1;
            }
        }
    }
    let burst = t0.elapsed();
    assert!(rejected >= 1, "burst never hit admission control");
    // the submit path must have failed fast, not waited for capacity
    assert!(burst.as_secs() < 5, "submissions blocked for {burst:?}");
    // every accepted request is still answered exactly once
    for rx in accepted {
        rx.recv().unwrap().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.rejected, rejected);
    assert_eq!(m.requests + rejected, 6);
    server.shutdown();
}

#[test]
fn pool_serves_concurrent_clients_consistently() {
    // the pool variant of the probe-drift test: four client threads, four
    // workers, a fixed probe input must produce bit-stable output no
    // matter which worker or batch serves it
    let mut rng = Rng::new(24);
    let (tt_model, _) = build_pair(&mut rng);
    let server = std::sync::Arc::new(Server::start(
        tt_model,
        ServeConfig { max_batch: 16, max_wait_us: 300, queue_cap: 512, workers: 4 },
    ));
    assert_eq!(server.workers(), 4);
    let probe: Vec<f32> = (0..784).map(|i| (i % 13) as f32 / 13.0).collect();
    let expected = server
        .infer(InferenceRequest { id: 0, input: probe.clone() })
        .unwrap()
        .output;

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let probe = probe.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(200 + t);
            for i in 0..25u64 {
                if i % 3 == 0 {
                    let out = server
                        .infer(InferenceRequest { id: t * 1000 + i, input: probe.clone() })
                        .unwrap()
                        .output;
                    for (a, b) in out.iter().zip(&expected) {
                        assert_eq!(a.to_bits(), b.to_bits(), "probe drifted across workers");
                    }
                } else {
                    let input = rng.normal_vec(784, 1.0);
                    server
                        .infer(InferenceRequest { id: t * 1000 + i, input })
                        .unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 1 + 4 * 25);
    assert!(m.mean_batch() >= 1.0);
}
