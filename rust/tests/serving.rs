//! Integration: the serving coordinator over a real TT-compressed model.

use ttrv::baselines::dense::DenseFc;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{
    InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine,
};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// Build a DSE-routed TT LeNet300 and an equivalent dense model (same
/// reconstructed weights) for output comparison.
fn build_pair(rng: &mut Rng) -> (ModelEngine, ModelEngine) {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut tt_ops = Vec::new();
    let mut dense_ops = Vec::new();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &cfg) {
            Route::Tt(sol) => {
                let tt = random_cores(&sol.layout, rng);
                let w = tt.reconstruct().unwrap();
                tt_ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.1, rng);
                tt_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None).unwrap()));
            }
        }
        if i + 1 < shapes.len() {
            tt_ops.push(LayerOp::Relu);
            dense_ops.push(LayerOp::Relu);
        }
    }
    (
        ModelEngine::new("lenet300-tt", tt_ops, 784, 10),
        ModelEngine::new("lenet300-dense", dense_ops, 784, 10),
    )
}

#[test]
fn served_outputs_match_dense_reference_model() {
    let mut rng = Rng::new(21);
    let (tt_model, mut dense_model) = build_pair(&mut rng);
    let server = Server::start(
        tt_model,
        ServeConfig { max_batch: 8, max_wait_us: 200, queue_cap: 128, workers: 1 },
    );
    for id in 0..24u64 {
        let input = rng.normal_vec(784, 1.0);
        let resp = server
            .infer(InferenceRequest { id, input: input.clone() })
            .unwrap();
        let x = Tensor::from_vec(vec![1, 784], input).unwrap();
        let want = dense_model.forward(&x).unwrap();
        for (a, b) in resp.output.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-2 + 1e-2 * b.abs(), "{a} vs {b}");
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests, 24);
    server.shutdown();
}

#[test]
fn concurrent_clients_get_consistent_replies() {
    let mut rng = Rng::new(22);
    let (tt_model, _) = build_pair(&mut rng);
    let server = std::sync::Arc::new(Server::start(
        tt_model,
        ServeConfig { max_batch: 16, max_wait_us: 300, queue_cap: 512, workers: 1 },
    ));
    // a fixed probe input must produce identical output regardless of the
    // batch it rides in
    let probe: Vec<f32> = (0..784).map(|i| (i % 13) as f32 / 13.0).collect();
    let expected = server
        .infer(InferenceRequest { id: 0, input: probe.clone() })
        .unwrap()
        .output;

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let probe = probe.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..25u64 {
                if i % 3 == 0 {
                    let out = server
                        .infer(InferenceRequest { id: t * 1000 + i, input: probe.clone() })
                        .unwrap()
                        .output;
                    for (a, b) in out.iter().zip(&expected) {
                        assert!((a - b).abs() < 1e-4, "probe drifted: {a} vs {b}");
                    }
                } else {
                    let input = rng.normal_vec(784, 1.0);
                    server
                        .infer(InferenceRequest { id: t * 1000 + i, input })
                        .unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests, 1 + 4 * 25);
    assert!(m.mean_batch() >= 1.0);
}

#[test]
fn throughput_improves_with_batching() {
    // serving sanity: under burst load the dynamic batcher forms multi-
    // request batches and every request is answered. Batching is
    // opportunistic (depends on scheduler interleaving on a 1-core host),
    // so the batching assertion is retried across bursts; losing a request
    // is never tolerated.
    let mut rng = Rng::new(23);
    let (tt_model, _) = build_pair(&mut rng);
    let server = Server::start(
        tt_model,
        ServeConfig { max_batch: 32, max_wait_us: 20_000, queue_cap: 512, workers: 1 },
    );
    let mut batched = false;
    for attempt in 0..5 {
        let inputs: Vec<Vec<f32>> = (0..128).map(|_| rng.normal_vec(784, 1.0)).collect();
        let rxs: Vec<_> = inputs
            .into_iter()
            .enumerate()
            .map(|(id, input)| {
                server
                    .submit(InferenceRequest { id: (attempt * 1000 + id) as u64, input })
                    .unwrap()
            })
            .collect();
        let mut max_batch = 0usize;
        for rx in rxs {
            max_batch = max_batch.max(rx.recv().unwrap().unwrap().batch_size);
        }
        assert!(max_batch <= 32);
        if max_batch > 1 {
            batched = true;
            break;
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests % 128, 0);
    assert!(batched, "no burst formed a multi-request batch in 5 attempts");
    server.shutdown();
}
