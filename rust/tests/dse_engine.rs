//! The six-stage DSE engine: golden Tables-1/2 stage counts, parallel ==
//! serial byte-identity, Pareto-frontier properties, canonical ordering.

use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::dse::pareto::dominates;
use ttrv::dse::{self, explore, explore_timed};
use ttrv::machine::MachineSpec;

fn k1() -> MachineSpec {
    MachineSpec::spacemit_k1()
}

/// Golden stage-3/4/5 counts for the Tables 1-2 layer set, `(n, m) ->
/// (vectorized, initial, scalability)`. These are the refactored pipeline's
/// own values, independently recomputed from the paper's counting rules;
/// any enumeration or cut change must be deliberate enough to re-derive
/// this table.
const GOLDEN: &[((u64, u64), (usize, usize, usize))] = &[
    // Table 1 (CNNs)
    ((400, 120), (684, 221, 218)),
    ((120, 84), (294, 56, 56)),
    ((784, 300), (1095, 557, 554)),
    ((300, 100), (322, 89, 89)),
    ((4096, 2048), (2895, 2667, 1913)),
    ((2048, 2048), (2133, 1898, 1403)),
    ((9216, 4096), (22609, 21922, 14483)),
    ((4096, 4096), (3986, 3759, 2612)),
    ((4096, 1000), (1973, 1661, 1546)),
    ((512, 512), (586, 362, 304)),
    ((512, 256), (408, 210, 184)),
    ((256, 100), (156, 41, 41)),
    ((25088, 4096), (17494, 17161, 12703)),
    ((2048, 1000), (1529, 1225, 1146)),
    ((1024, 1000), (1202, 889, 839)),
    // Table 2 (LLMs: GPT2-Medium and GPT3-Ada rows)
    ((1024, 1024), (1173, 907, 729)),
    ((1024, 4096), (2104, 1840, 1389)),
    ((4096, 1024), (2104, 1840, 1389)),
    ((1024, 50257), (40, 34, 34)),
    ((768, 768), (3607, 2532, 2126)),
    ((768, 3072), (7238, 6047, 4777)),
    ((3072, 768), (7238, 6047, 4777)),
    ((768, 50257), (64, 55, 55)),
];

#[test]
fn golden_tables_stage_counts_through_the_refactored_pipeline() {
    let cfg = DseConfig::default();
    for &((n, m), (vectorized, initial, scalability)) in GOLDEN {
        let e = explore(m, n, &cfg);
        assert_eq!(
            (e.counts.vectorized, e.counts.initial, e.counts.scalability),
            (vectorized, initial, scalability),
            "stage counts drifted for [{n}, {m}]"
        );
        assert_eq!(e.survivors.len(), scalability, "[{n}, {m}]");
        assert!(e.counts.all >= e.counts.aligned, "[{n}, {m}]");
        assert!(e.counts.aligned >= vectorized as f64, "[{n}, {m}]");
    }
}

#[test]
fn parallel_exploration_is_byte_identical_to_serial() {
    // the acceptance bar: dse_workers = 4 must reproduce dse_workers = 1
    // exactly — stage counts, the survivor list, stage-6 pricing, and the
    // frontier, all compared structurally (f64 times included)
    for (n, m) in [(784u64, 300u64), (2048, 1000)] {
        let serial = explore_timed(m, n, &k1(), &DseConfig::default());
        for workers in [2usize, 4] {
            let cfg = DseConfig { dse_workers: workers, ..Default::default() };
            let parallel = explore_timed(m, n, &k1(), &cfg);
            assert_eq!(parallel, serial, "[{n}, {m}] workers={workers}");
        }
        // and the five-stage view is the untimed pipeline's, verbatim
        assert_eq!(serial.explored, explore(m, n, &DseConfig::default()));
    }
}

#[test]
fn frontier_contains_no_dominated_solution() {
    let e = explore_timed(300, 784, &k1(), &DseConfig::default());
    assert!(!e.frontier.is_empty());
    for (i, a) in e.frontier.iter().enumerate() {
        for (j, b) in e.frontier.iter().enumerate() {
            if i != j {
                assert!(
                    !dominates(a, b),
                    "frontier member {} dominates {}",
                    a.layout().describe(),
                    b.layout().describe()
                );
            }
        }
    }
}

#[test]
fn every_pruned_solution_is_dominated_by_a_frontier_member() {
    let e = explore_timed(300, 784, &k1(), &DseConfig::default());
    assert!(e.frontier.len() < e.timed.len(), "pruning must bite here");
    for s in &e.timed {
        if e.frontier.contains(s) {
            continue;
        }
        assert!(
            e.frontier.iter().any(|f| dominates(f, s)),
            "{} pruned from the frontier but undominated",
            s.layout().describe()
        );
    }
}

#[test]
fn property_frontier_invariants_on_random_layers() {
    ttrv::testkit::check("pareto invariants", 8, |d| {
        let m = 8 * d.usize_in(2, 48) as u64;
        let n = 8 * d.usize_in(2, 48) as u64;
        let e = explore_timed(m, n, &k1(), &DseConfig::default());
        if e.timed.is_empty() {
            if !e.frontier.is_empty() {
                return Err("frontier nonempty with no timed survivors".into());
            }
            return Ok(());
        }
        if e.frontier.is_empty() {
            return Err(format!("[{n},{m}]: timed solutions but empty frontier"));
        }
        for f in &e.frontier {
            if e.timed.iter().any(|o| dominates(o, f)) {
                return Err(format!("dominated frontier member {}", f.layout().describe()));
            }
        }
        for s in &e.timed {
            let on_frontier = e.frontier.contains(s);
            let dominated = e.frontier.iter().any(|f| dominates(f, s));
            if !on_frontier && !dominated {
                return Err(format!("{} neither on frontier nor dominated", s.layout().describe()));
            }
            if on_frontier && dominated {
                return Err("frontier member dominated by another member".into());
            }
        }
        Ok(())
    });
}

#[test]
fn survivor_tie_ordering_is_canonical_and_deterministic() {
    // (flops, params, rank, shape-lexicographic): ties beyond FLOPs (which
    // the old FLOPs-only sort left in enumeration order) are now pinned
    let e = explore(512, 512, &DseConfig::default());
    for w in e.survivors.windows(2) {
        let a = &w[0];
        let b = &w[1];
        assert_eq!(a.canonical_cmp(b), std::cmp::Ordering::Less);
        let key = |s: &ttrv::dse::Solution| {
            (s.flops, s.params, s.rank, s.layout.m_shape().to_vec(), s.layout.n_shape().to_vec())
        };
        let (ka, kb) = (key(a), key(b));
        assert!(ka < kb, "{ka:?} !< {kb:?}");
    }
    // the timed list and frontier inherit the same order
    let te = explore_timed(512, 512, &k1(), &DseConfig::default());
    for w in te.timed.windows(2) {
        assert_eq!(w[0].solution.canonical_cmp(&w[1].solution), std::cmp::Ordering::Less);
    }
    for w in te.frontier.windows(2) {
        assert_eq!(w[0].solution.canonical_cmp(&w[1].solution), std::cmp::Ordering::Less);
    }
}

#[test]
fn selection_substrate_is_the_timed_engine_output() {
    // both policies return stage-6-qualified solutions; min-time's pick is
    // a frontier member, and raw stage-5 survivors that failed pricing are
    // never selectable
    let cfg = DseConfig::default();
    let e = explore_timed(2048, 4096, &k1(), &cfg);
    let bal = dse::select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
    assert!(e.timed.contains(&bal));
    let fast = dse::select_solution(&e, 8, SelectionPolicy::MinTime).unwrap();
    assert!(e.frontier.contains(&fast));
    assert!(fast.time_s <= bal.time_s);
    // this layer has stage-5 survivors that stage 6 discards (unschedulable
    // or below-threshold); the engine keeps the accounting visible
    assert!(e.timed.len() < e.explored.counts.scalability);
}
