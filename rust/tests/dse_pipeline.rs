//! Integration: DSE -> compiler -> kernel engine, over the model zoo.

use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::coordinator::TtFcEngine;
use ttrv::dse;
use ttrv::machine::MachineSpec;
use ttrv::models;
use ttrv::tensor::einsum::fc_batched_ref;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::{random_cores, tt_svd};
use ttrv::ttd::{cost, TtLayout};
use ttrv::util::prng::Rng;

#[test]
fn zoo_cnn_layers_explore_cleanly() {
    let cfg = DseConfig::default();
    for model in models::cnn_models() {
        for fc in model.fc_shapes() {
            if fc.m < 64 || fc.n < 64 {
                continue;
            }
            let e = dse::explore(fc.m, fc.n, &cfg);
            // stage monotonicity on real shapes
            assert!(e.counts.all >= e.counts.aligned);
            assert!(e.counts.aligned >= e.counts.vectorized as f64);
            assert!(e.counts.vectorized >= e.counts.initial);
            assert!(e.counts.initial >= e.counts.scalability);
            // every sizeable layer must retain at least one solution
            assert!(
                !e.survivors.is_empty(),
                "{} [{}, {}] lost all solutions",
                model.name,
                fc.n,
                fc.m
            );
        }
    }
}

#[test]
fn selected_solutions_execute_and_beat_dense_flops() {
    let cfg = DseConfig::default();
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(11);
    // the Fig. 15 model set (Sec. 6.4 shapes)
    for (n, m) in [(2048u64, 1000u64), (512, 512), (4096, 2048), (1024, 1000)] {
        let e = dse::explore_timed(m, n, &machine, &cfg);
        let sol = dse::select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert_eq!(sol.layout().d(), 2, "Sec 6.4 policy picks d=2 for [{n},{m}]");
        assert!(sol.solution.flops < cost::dense_flops(m, n));
        // stage 6 guarantees a modeled win on the target machine too
        assert!(sol.speedup >= cfg.time_speedup_min, "[{n},{m}]");
        // the selected layout must compile + run through the engine
        let tt = random_cores(sol.layout(), &mut rng);
        let mut engine = TtFcEngine::new(&tt, &machine).unwrap();
        let x = Tensor::randn(vec![2, n as usize], 1.0, &mut rng);
        let w = tt.reconstruct().unwrap();
        let got = engine.forward(&x).unwrap();
        let want = fc_batched_ref(&w, &x, None).unwrap();
        assert!(
            got.allclose(&want, 1e-2, 1e-2),
            "[{n},{m}]: maxdiff {}",
            got.max_abs_diff(&want).unwrap()
        );
    }
}

#[test]
fn dse_plus_ttsvd_roundtrip_on_real_layer_shape() {
    // decompose an actual (random) 784x300 weight matrix with the
    // DSE-selected layout and verify approximation + compression
    let cfg = DseConfig::default();
    let mut rng = Rng::new(12);
    let e = dse::explore_timed(300, 784, &MachineSpec::spacemit_k1(), &cfg);
    let sol = dse::select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
    // a W that is exactly TT-rank 8 in the selected layout
    let truth = random_cores(sol.layout(), &mut rng);
    let w = truth.reconstruct().unwrap();
    let tt = tt_svd(&w, sol.layout()).unwrap();
    assert!(tt.rel_error(&w).unwrap() < 1e-3);
    assert!(cost::params(&tt.layout) < cost::dense_params(300, 784) / 10);
}

#[test]
fn alternates_allow_accuracy_fallback() {
    // the paper's flexibility claim: a list of solutions, not just one
    let cfg = DseConfig::default();
    let e = dse::explore_timed(1000, 2048, &MachineSpec::spacemit_k1(), &cfg);
    let alts = dse::select::alternates(&e, 8);
    assert!(alts.len() >= 3, "need fallback candidates, got {}", alts.len());
    // all alternates are valid layouts with distinct (layout, rank)
    let mut seen = std::collections::HashSet::new();
    for a in &alts {
        assert!(a.layout().ranks_feasible());
        assert!(seen.insert(format!("{}@{}", a.layout().describe(), a.solution.rank)));
        // ...and every fallback already cleared the modeled-time bar
        assert!(a.speedup >= cfg.time_speedup_min);
    }
}

#[test]
fn paper_running_example_survives_pipeline() {
    // the Sec. 2 example (m=[5,5,3,2,2], n=[2,2,2,7,14], R=8) is aligned and
    // must appear among enumerated solutions before the scalability cut
    let cfg = DseConfig::default();
    let e = dse::explore(300, 784, &cfg);
    let target = TtLayout::with_uniform_rank(
        vec![5, 5, 3, 2, 2],
        vec![2, 2, 2, 7, 14],
        8,
    )
    .unwrap();
    // d=5 > 4 and light einsums -> the scalability constraint prunes it
    let in_survivors = e.survivors.iter().any(|s| s.layout == target);
    assert!(!in_survivors, "d=5 light config should be scalability-pruned");
    // but the d=2 solution the paper ultimately uses survives
    assert!(e.survivors.iter().any(|s| s.layout.d() == 2 && s.rank == 8));
}
