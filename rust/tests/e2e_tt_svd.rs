//! Integration: TT-SVD compression of realistic weight matrices + engine
//! execution — compression/accuracy invariants across layouts.

use ttrv::config::DseConfig;
use ttrv::coordinator::TtFcEngine;
use ttrv::dse;
use ttrv::linalg::matmul;
use ttrv::machine::MachineSpec;
use ttrv::tensor::einsum::fc_batched_ref;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::tt_svd;
use ttrv::ttd::{cost, TtLayout};
use ttrv::util::prng::Rng;

/// A synthetic "trained" weight matrix with decaying spectrum (real FC
/// layers are approximately low-rank; pure white noise is the worst case).
fn lowrankish(m: usize, n: usize, rng: &mut Rng) -> Tensor {
    let k = m.min(n);
    let u = Tensor::randn(vec![m, k], 1.0, rng);
    let mut v = Tensor::randn(vec![k, n], 1.0, rng);
    for (i, row) in v.data_mut().chunks_mut(n).enumerate() {
        let scale = 1.0 / (1.0 + i as f32).powf(2.0);
        row.iter_mut().for_each(|x| *x *= scale);
    }
    matmul(&u, &v).unwrap()
}

#[test]
fn compression_error_tradeoff_is_monotone() {
    let mut rng = Rng::new(41);
    let w = lowrankish(120, 400, &mut rng);
    let mut last_err = f32::INFINITY;
    let mut last_params = 0;
    let mut errs = Vec::new();
    for r in [4u64, 8, 16, 32] {
        let layout = TtLayout::with_uniform_rank(vec![12, 10], vec![20, 20], r).unwrap();
        let tt = tt_svd(&w, &layout).unwrap();
        let err = tt.rel_error(&w).unwrap();
        assert!(err <= last_err + 1e-5, "rank {r}: error went up");
        assert!(tt.param_count() >= last_params, "rank {r}: params shrank");
        last_err = err;
        last_params = tt.param_count();
        errs.push(err);
    }
    // The TT-rank spectrum of the interleaved matricization decays much more
    // slowly than W's own SVD spectrum (a matrix-low-rank W is NOT TT-low-
    // rank), so assert the *tradeoff shape*, not an absolute error: strictly
    // better at each rank and a meaningful cumulative improvement.
    assert!(last_err < 0.85 * errs[0], "no meaningful improvement: {errs:?}");
}

#[test]
fn full_rank_decomposition_is_exact() {
    // the top of the rank ladder: requesting the attainable bound
    // min(m1*n1, m2*n2) must reproduce W to float roundoff, so the rank
    // sweep's rel_error axis bottoms out near 0 instead of plateauing
    let mut rng = Rng::new(44);
    let w = lowrankish(120, 400, &mut rng);
    let bound = (12u64 * 20).min(10 * 20);
    let layout = TtLayout::with_uniform_rank(vec![12, 10], vec![20, 20], bound).unwrap();
    let tt = tt_svd(&w, &layout).unwrap();
    let err = tt.rel_error(&w).unwrap();
    assert!(err < 1e-3, "full-rank TT-SVD not exact: rel_error {err}");
}

#[test]
fn engine_inference_error_bounded_by_decomposition_error() {
    let mut rng = Rng::new(42);
    let w = lowrankish(120, 400, &mut rng);
    let layout = TtLayout::with_uniform_rank(vec![12, 10], vec![20, 20], 16).unwrap();
    let mut tt = tt_svd(&w, &layout).unwrap();
    tt.bias = Some(vec![0.0; 120]);
    let w_hat = tt.reconstruct().unwrap();
    let mut engine = TtFcEngine::new(&tt, &MachineSpec::spacemit_k1()).unwrap();
    let x = Tensor::randn(vec![8, 400], 1.0, &mut rng);
    let got = engine.forward(&x).unwrap();
    // engine output == reconstruction output (engine adds no extra error)
    let recon = fc_batched_ref(&w_hat, &x, Some(&vec![0.0; 120])).unwrap();
    assert!(
        got.allclose(&recon, 1e-3, 1e-3),
        "engine vs reconstruction: {}",
        got.max_abs_diff(&recon).unwrap()
    );
    // and approximates the original weights at the decomposition error scale
    let exact = fc_batched_ref(&w, &x, Some(&vec![0.0; 120])).unwrap();
    let rel = got.rel_l2_error(&exact).unwrap();
    let decomp_rel = w_hat.rel_l2_error(&w).unwrap();
    assert!(rel < 4.0 * decomp_rel + 1e-3, "inference rel {rel} vs decomp {decomp_rel}");
}

#[test]
fn dse_selected_layouts_decompose_every_zoo_cnn_layer() {
    // for each mid-size CNN FC layer: DSE-select, TT-SVD, check compression
    let cfg = DseConfig::default();
    let mut rng = Rng::new(43);
    for (n, m) in [(400u64, 120u64), (512, 256)] {
        let e = dse::explore_timed(m, n, &MachineSpec::spacemit_k1(), &cfg);
        let sol = dse::select_solution(&e, 8, ttrv::config::SelectionPolicy::Balance).unwrap();
        let w = lowrankish(m as usize, n as usize, &mut rng);
        let tt = tt_svd(&w, sol.layout()).unwrap();
        assert!(
            (tt.param_count() as u64) < cost::dense_params(m, n),
            "[{n},{m}] did not compress"
        );
        assert!(tt.rel_error(&w).unwrap() < 0.9);
    }
}

#[test]
fn property_ttsvd_never_increases_achieved_rank_beyond_request() {
    ttrv::testkit::check("tt-svd rank clipping", 10, |d| {
        let mut rng = d.rng().fork();
        let m1 = d.usize_in(2, 6) as u64;
        let m2 = d.usize_in(2, 6) as u64;
        let n1 = d.usize_in(2, 6) as u64;
        let n2 = d.usize_in(2, 6) as u64;
        let req = d.usize_in(1, 16) as u64;
        let w = Tensor::randn(vec![(m1 * m2) as usize, (n1 * n2) as usize], 1.0, &mut rng);
        let layout = TtLayout::with_uniform_rank(vec![m1, m2], vec![n1, n2], req)
            .map_err(|e| e.to_string())?;
        let tt = tt_svd(&w, &layout).map_err(|e| e.to_string())?;
        let achieved = tt.layout.ranks()[1];
        let bound = (m1 * n1).min(m2 * n2);
        if achieved > req || achieved > bound {
            return Err(format!("achieved {achieved} > req {req} or bound {bound}"));
        }
        // full-rank request => exact reconstruction
        if req >= bound {
            let err = tt.rel_error(&w).map_err(|e| e.to_string())?;
            if err > 1e-3 {
                return Err(format!("full-rank not exact: {err}"));
            }
        }
        Ok(())
    });
}
