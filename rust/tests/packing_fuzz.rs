//! Property/fuzz coverage of operand packing and the packed-buffer
//! contracts the unsafe vector microkernels rely on.
//!
//! `pack` itself is safe Rust, but the vector kernels trust its two
//! invariants with raw-pointer loads: (1) a `PackedR` buffer holds exactly
//! `m * r_pad * n*k` lanes with every out-of-range-r lane zeroed, and
//! (2) a `PackedK` buffer holds exactly `m * r * n*k` contiguous
//! contraction rows. This suite fuzzes arbitrary `(r, n, m, k)` —
//! including degenerate all-1 extents — and checks:
//!
//! * pack -> unpack roundtrips **bitwise** to the canonical core for all
//!   three layouts (no value is dropped, duplicated, or rounded);
//! * buffer lengths are exactly the layout formulas (nothing for a kernel
//!   to read past, nothing unwritten);
//! * `PackedR` zero-padding: every lane with `r <= lane_r < r_pad` is 0.0;
//! * the packed buffers actually execute: every registered kernel runs the
//!   fuzzed shapes end to end, which is what the sanitizer CI job (ASan,
//!   `TTRV_FORCE_SCALAR` off) leans on to catch out-of-bounds reads in the
//!   unsafe `target_feature` regions;
//! * the int8 shadow holds the same contracts: `quantize` preserves the
//!   buffer length / index formulas / zero pad lanes of every layout,
//!   `dequantize` reconstructs within half a quantization step per
//!   `m`-slice, and every kernel's `*_q` regions execute quantized cores
//!   in bounds (the int8 half of the ASan surface).

use ttrv::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
use ttrv::kernels::{dequantize, pack, quantize, Executor, GLayout, Kernel, VL};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};

// Miri executes a few hundred times slower than native, so the CI Miri job
// trims the fuzz budget: each case still walks every layout and kernel, and
// undefined behaviour is per-operation, not per-iteration.
#[cfg(miri)]
const FUZZ_CASES: usize = 3;
#[cfg(not(miri))]
const FUZZ_CASES: usize = 40;
#[cfg(miri)]
const EXEC_CASES: usize = 2;
#[cfg(not(miri))]
const EXEC_CASES: usize = 25;

fn kind_of(r: usize, k: usize) -> EinsumKind {
    if k == 1 {
        EinsumKind::First
    } else if r == 1 {
        EinsumKind::Final
    } else {
        EinsumKind::Middle
    }
}

fn plan_for(dims: EinsumDims, vloop: VectorLoop, pack_g: bool, rb: RbFactors) -> OptimizationPlan {
    OptimizationPlan {
        dims,
        pack_g,
        vector_loop: vloop,
        vl: if vloop == VectorLoop::None { 1 } else { VL },
        rb,
        tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
        threads: 1,
        ls_estimate: 0,
    }
}

/// Invert a packed buffer back to the canonical `[r][n][m][k]` order.
fn unpack(p: &ttrv::kernels::PackedG) -> Vec<f32> {
    let (r, n, m, k) = p.dims;
    let l = n * k;
    let mut out = vec![0.0f32; r * n * m * k];
    for ri in 0..r {
        for ni in 0..n {
            for mi in 0..m {
                for ki in 0..k {
                    let kk = ni * k + ki;
                    let v = match p.layout {
                        GLayout::Canonical => p.data[((ri * n + ni) * m + mi) * k + ki],
                        GLayout::PackedR => {
                            let (rv, lane) = (ri / VL, ri % VL);
                            p.data[((mi * (p.r_pad / VL) + rv) * l + kk) * VL + lane]
                        }
                        GLayout::PackedK => p.data[(mi * r + ri) * l + kk],
                    };
                    out[((ri * n + ni) * m + mi) * k + ki] = v;
                }
            }
        }
    }
    out
}

#[test]
fn property_pack_unpack_roundtrips_bitwise_for_all_layouts() {
    ttrv::testkit::check("pack -> unpack == id", FUZZ_CASES, |d| {
        // degenerate 1s are first-class citizens of every extent
        let r = d.usize_in(1, 20);
        let n = d.usize_in(1, 6);
        let m = d.usize_in(1, 10);
        let k = d.usize_in(1, 20);
        let dims = EinsumDims { kind: kind_of(r, k), m, b: 2, n, r, k };
        let mut rng = d.rng().fork();
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        for (vloop, pack_g, layout, len) in [
            (VectorLoop::None, false, GLayout::Canonical, r * n * m * k),
            (VectorLoop::R, true, GLayout::PackedR, m * r.div_ceil(VL) * VL * n * k),
            (VectorLoop::K, true, GLayout::PackedK, m * r * n * k),
            // the scalar kernel shares the PackedK layout
            (VectorLoop::None, true, GLayout::PackedK, m * r * n * k),
        ] {
            let p = pack(&g, &plan_for(dims, vloop, pack_g, RbFactors::NONE))
                .map_err(|e| e.to_string())?;
            if p.layout != layout {
                return Err(format!("{vloop:?}: layout {:?}, want {layout:?}", p.layout));
            }
            if p.data.len() != len {
                return Err(format!("{vloop:?}: {} lanes, want {len}", p.data.len()));
            }
            let back = unpack(&p);
            if back != g.data() {
                return Err(format!("{vloop:?}: unpack is not the canonical core"));
            }
            if p.layout == GLayout::PackedR {
                if p.r_pad != r.div_ceil(VL) * VL {
                    return Err(format!("r_pad {} for r {r}", p.r_pad));
                }
                // every out-of-range lane must be exactly zero: the
                // r-kernels multiply-accumulate them unconditionally
                for mi in 0..m {
                    for rv in 0..p.r_pad / VL {
                        for kk in 0..n * k {
                            let base = ((mi * (p.r_pad / VL) + rv) * (n * k) + kk) * VL;
                            for lane in 0..VL {
                                if rv * VL + lane < r {
                                    continue;
                                }
                                let v = p.data[base + lane];
                                if v != 0.0 {
                                    return Err(format!("pad lane ({mi},{rv},{kk},{lane}) = {v}"));
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Quantize -> dequantize over fuzzed shapes and all three layouts: the
/// int8 buffer is index-compatible with its f32 twin (same length, same
/// formulas, `PackedR` pad lanes still exactly zero), scales are
/// per-`m`-slice positive finite, and reconstruction lands within half a
/// quantization step of every original value — the invariants the int8
/// kernels and the QUANT section reader both trust.
#[test]
fn property_quantize_roundtrips_within_half_step_for_all_layouts() {
    ttrv::testkit::check("quantize -> dequantize within step/2", FUZZ_CASES, |d| {
        let r = d.usize_in(1, 20);
        let n = d.usize_in(1, 6);
        let m = d.usize_in(1, 10);
        let k = d.usize_in(1, 20);
        let dims = EinsumDims { kind: kind_of(r, k), m, b: 2, n, r, k };
        let mut rng = d.rng().fork();
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        for (vloop, pack_g) in [
            (VectorLoop::None, false), // Canonical
            (VectorLoop::R, true),     // PackedR
            (VectorLoop::K, true),     // PackedK
        ] {
            let p = pack(&g, &plan_for(dims, vloop, pack_g, RbFactors::NONE))
                .map_err(|e| e.to_string())?;
            let q = quantize(&p);
            if q.layout != p.layout || q.dims != p.dims || q.r_pad != p.r_pad {
                return Err(format!("{vloop:?}: quantize changed the layout descriptor"));
            }
            if q.data.len() != p.data.len() {
                return Err(format!(
                    "{vloop:?}: {} int8 lanes for {} f32 lanes",
                    q.data.len(),
                    p.data.len()
                ));
            }
            if q.scales.len() != m {
                return Err(format!("{vloop:?}: {} scales for m = {m}", q.scales.len()));
            }
            if q.scales.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                return Err(format!("{vloop:?}: non-positive scale"));
            }
            // the int8 resident footprint is ~4x smaller by construction
            if q.bytes() >= p.bytes() {
                return Err(format!("{vloop:?}: int8 bytes {} >= f32 {}", q.bytes(), p.bytes()));
            }
            // pad lanes quantize to exactly zero (kernels MAC them blindly)
            if q.layout == GLayout::PackedR {
                for (i, (&fv, &qv)) in p.data.iter().zip(&q.data).enumerate() {
                    if fv == 0.0 && qv != 0 {
                        return Err(format!("{vloop:?}: zero lane {i} quantized to {qv}"));
                    }
                }
            }
            // reconstruction: per-slice bound |deq - g| <= scale/2
            let back = dequantize(&q);
            for (i, (&a, &b)) in p.data.iter().zip(&back.data).enumerate() {
                let owner = match p.layout {
                    GLayout::Canonical => (i / k) % m,
                    GLayout::PackedR => i / (p.r_pad * n * k),
                    GLayout::PackedK => i / (r * n * k),
                };
                let bound = q.scales[owner] * 0.5 + 1e-6;
                if (a - b).abs() > bound {
                    return Err(format!(
                        "{vloop:?}: slice {owner} lane {i}: |{a} - {b}| > {bound}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Drive every registered kernel over fuzzed shapes end to end. Values are
/// checked elsewhere (`kernel_reference.rs`); here the point is that the
/// unsafe load/store regions stay inside the packed buffers for arbitrary
/// extents — the sanitizer CI job runs this binary with ASan and
/// `TTRV_FORCE_SCALAR` off so the vector kernels are the ones executing.
#[test]
fn property_every_kernel_executes_fuzzed_shapes_in_bounds() {
    let machine = MachineSpec::spacemit_k1();
    ttrv::testkit::check("kernels stay in bounds", EXEC_CASES, |d| {
        let r = d.usize_in(1, 20);
        let n = d.usize_in(1, 5);
        let m = d.usize_in(1, 12);
        let k = d.usize_in(1, 20);
        let b = d.usize_in(1, 12);
        let dims = EinsumDims { kind: kind_of(r, k), m, b, n, r, k };
        let mut rng = d.rng().fork();
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let rbf = RbFactors {
            rm: *d.choose(&[1usize, 2, 4, 8]),
            rb: d.usize_in(1, 8),
            rr: 1,
            rk: 1,
        };
        for &kernel in ttrv::kernels::all_kernels() {
            if !kernel.supported() {
                continue;
            }
            let mut ex = Executor::with_kernel(&machine, kernel).map_err(|e| e.to_string())?;
            for (vloop, pack_g, rb) in [
                (VectorLoop::None, false, RbFactors::NONE),
                (VectorLoop::None, true, RbFactors::NONE),
                (VectorLoop::K, true, RbFactors::NONE),
                (VectorLoop::R, true, rbf),
            ] {
                let plan = plan_for(dims, vloop, pack_g, rb);
                let pg = pack(&g, &plan).map_err(|e| e.to_string())?;
                ex.set_plan(plan).map_err(|e| e.to_string())?;
                let out = ex.execute(&dims, &pg, &x).map_err(|e| e.to_string())?;
                if out.dims() != [m, b, r].as_slice() {
                    return Err(format!(
                        "kernel {} {vloop:?}: output dims {:?}",
                        kernel.name(),
                        out.dims()
                    ));
                }
                if out.data().iter().any(|v| !v.is_finite()) {
                    return Err(format!("kernel {} {vloop:?}: non-finite output", kernel.name()));
                }
            }
        }
        Ok(())
    });
}

/// The int8 twin of the in-bounds property: every registered kernel
/// executes fuzzed shapes over *quantized* cores through every plan
/// family (every kernel has `*_q` regions — f32 kernels inherit the
/// portable int8 reference, int8 kernels run their widening SIMD). The
/// ASan CI job leans on this to bound the unsafe int8 vector regions.
#[test]
fn property_every_kernel_executes_quantized_fuzzed_shapes_in_bounds() {
    let machine = MachineSpec::spacemit_k1();
    ttrv::testkit::check("int8 kernels stay in bounds", EXEC_CASES, |d| {
        let r = d.usize_in(1, 20);
        let n = d.usize_in(1, 5);
        let m = d.usize_in(1, 12);
        let k = d.usize_in(1, 20);
        let b = d.usize_in(1, 12);
        let dims = EinsumDims { kind: kind_of(r, k), m, b, n, r, k };
        let mut rng = d.rng().fork();
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let rbf = RbFactors {
            rm: *d.choose(&[1usize, 2, 4, 8]),
            rb: d.usize_in(1, 8),
            rr: 1,
            rk: 1,
        };
        for &kernel in ttrv::kernels::all_kernels() {
            if !kernel.supported() {
                continue;
            }
            let mut ex = Executor::with_kernel(&machine, kernel).map_err(|e| e.to_string())?;
            for (vloop, pack_g, rb) in [
                (VectorLoop::None, false, RbFactors::NONE),
                (VectorLoop::None, true, RbFactors::NONE),
                (VectorLoop::K, true, RbFactors::NONE),
                (VectorLoop::R, true, rbf),
            ] {
                let plan = plan_for(dims, vloop, pack_g, rb);
                let qg = quantize(&pack(&g, &plan).map_err(|e| e.to_string())?);
                ex.set_plan(plan).map_err(|e| e.to_string())?;
                let out = ex.execute_q(&dims, &qg, &x).map_err(|e| e.to_string())?;
                if out.dims() != [m, b, r].as_slice() {
                    return Err(format!(
                        "kernel {} {vloop:?}: q output dims {:?}",
                        kernel.name(),
                        out.dims()
                    ));
                }
                if out.data().iter().any(|v| !v.is_finite()) {
                    return Err(format!(
                        "kernel {} {vloop:?}: non-finite int8 output",
                        kernel.name()
                    ));
                }
            }
        }
        Ok(())
    });
}
