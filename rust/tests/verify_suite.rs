//! Static-verifier suite (ISSUE 10): the adversarial mutant corpus and the
//! three chokepoint pins.
//!
//! * **Mutant corpus** — ≥10 hand-corrupted bundles (bad `r_pad`,
//!   over-budget RB, k-tail overruns, layout/plan mismatches, int8 scale
//!   faults, poisoned pad lanes, ...) that the verifier must reject with a
//!   diagnostic naming the violated invariant by its stable slug, and that
//!   the artifact reader must refuse to decode after a byte round-trip.
//! * **Clean pins** — the golden `tests/data/lenet300.ttrv`, fresh
//!   compressions (f32 / +QUANT / +TUNE-shaped) and the *entire* model
//!   zoo's DSE-selected plan chains all lint clean: the verifier has zero
//!   false positives on everything the compiler itself produces.
//! * **Chokepoints** — plans reach kernels only through (1) executor
//!   cache inserts (`executor.rs` unit tests), (2) `read_bundle_bytes`
//!   (pinned here + `reader.rs`), (3) `ttrv lint` (the same
//!   `lint_bundle` walk pinned here).

use std::sync::OnceLock;

use ttrv::artifact::{self, BundleOp, CompressSpec, ModelBundle};
use ttrv::compiler::verify::{check_packed, check_plan_for, check_quant};
use ttrv::compiler::{compile, RbFactors};
use ttrv::config::DseConfig;
use ttrv::coordinator::{router, Route};
use ttrv::error::Error;
use ttrv::kernels::{pack, quantize, VL};
use ttrv::machine::MachineSpec;
use ttrv::models;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{einsum_chain, EinsumDims, EinsumKind};
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

fn k1() -> MachineSpec {
    MachineSpec::spacemit_k1()
}

/// One deterministic compressed LeNet300 with an int8 QUANT shadow and a
/// TUNE-shaped plan list, shared by every mutant (cloned per mutation).
fn base_bundle() -> &'static ModelBundle {
    static CELL: OnceLock<ModelBundle> = OnceLock::new();
    CELL.get_or_init(|| {
        let spec = CompressSpec::from_zoo("lenet300", 8, 5).unwrap();
        let mut b = artifact::compress(&spec, &k1(), &DseConfig::default()).unwrap();
        artifact::quantize_bundle(&mut b, &k1(), None).unwrap();
        // a TUNE section without measurement: re-using the analytic plans
        // is exactly the shape `tune_bundle` persists (tuning never changes
        // dims or layouts), and it exercises the tuned-plan lint walk
        for op in &mut b.ops {
            if let BundleOp::Tt(t) = op {
                t.tuned = Some(t.plans.clone());
            }
        }
        b.tuned_kernel = Some("portable".to_string());
        b
    })
}

/// First TT layer of a bundle, mutably.
fn tt0(b: &mut ModelBundle) -> &mut ttrv::artifact::TtLayerBundle {
    b.ops
        .iter_mut()
        .find_map(|op| match op {
            BundleOp::Tt(t) => Some(t),
            _ => None,
        })
        .expect("bundle has a TT layer")
}

/// The adversarial corpus: every mutation must (a) be named by the lint
/// walk with the expected invariant slug and (b) make the byte-roundtrip
/// reader refuse the bundle with a typed `Error::Artifact` — whether the
/// decode grammar or the static-verification gate catches it first.
#[test]
fn mutant_corpus_rejected_with_named_invariants() {
    type Mutation = (&'static str, &'static str, fn(&mut ModelBundle));
    let corpus: [Mutation; 14] = [
        ("r_pad-too-small", "rpad-formula", |b| {
            tt0(b).packed[0].r_pad -= 1;
        }),
        ("rb-over-register-budget", "rb-register-budget", |b| {
            tt0(b).plans[0].rb = RbFactors { rm: 8, rb: 8, rr: 1, rk: 1 };
        }),
        ("k-tail-overrun-f32", "buffer-length", |b| {
            tt0(b).packed[0].data.pop();
        }),
        ("k-tail-overrun-int8", "buffer-length", |b| {
            let t = tt0(b);
            let q = t.quant.as_mut().expect("quantized");
            q[0].data.pop();
        }),
        ("layout-plan-mismatch", "layout-consistent", |b| {
            let t = tt0(b);
            t.plans[0].pack_g = !t.plans[0].pack_g;
        }),
        ("plan-core-dims-mismatch", "core-dims-match", |b| {
            tt0(b).plans[0].dims.m += 1;
        }),
        ("int8-scale-count-mismatch", "quant-scale-count", |b| {
            let t = tt0(b);
            t.quant.as_mut().expect("quantized")[0].scales.pop();
        }),
        ("int8-scale-nan", "quant-scale-finite", |b| {
            let t = tt0(b);
            t.quant.as_mut().expect("quantized")[0].scales[0] = f32::NAN;
        }),
        ("int8-value-minus-128", "quant-value-range", |b| {
            let t = tt0(b);
            t.quant.as_mut().expect("quantized")[0].data[0] = i8::MIN;
        }),
        ("threads-zero", "threads-positive", |b| {
            tt0(b).plans[1].threads = 0;
        }),
        ("rm-zero", "rb-range", |b| {
            tt0(b).plans[0].rb.rm = 0;
        }),
        ("vl-claims-half-vector", "vl-matches-packing", |b| {
            tt0(b).plans[0].vl = VL / 2;
        }),
        ("btl-zero-tile", "btl-positive", |b| {
            tt0(b).plans[0].tile.btl = Some(0);
        }),
        ("tuned-plan-corrupt", "threads-positive", |b| {
            let t = tt0(b);
            t.tuned.as_mut().expect("tuned")[0].threads = 0;
        }),
    ];
    for (name, slug, mutate) in corpus {
        let mut b = base_bundle().clone();
        mutate(&mut b);
        // (a) the lint walk names the violated invariant
        let report = artifact::lint_bundle(&b);
        assert!(!report.clean(), "{name}: lint failed to flag the mutant");
        let slugs: Vec<&str> = report
            .rows
            .iter()
            .flat_map(|r| r.violations.iter().map(|v| v.invariant))
            .collect();
        assert!(slugs.contains(&slug), "{name}: expected '{slug}' in {slugs:?}");
        // the fail-fast twin is a typed Error::Artifact naming it too
        let err = artifact::verify_bundle(&b).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{name}: {err}");
        assert!(err.to_string().contains(slug), "{name}: {err}");
        // (b) the byte round-trip cannot smuggle it past the reader: either
        // the section grammar or the static-verification gate rejects
        let bytes = artifact::write_bundle(&b);
        let err = artifact::read_bundle_bytes(&bytes)
            .expect_err(&format!("{name}: reader accepted a corrupt bundle"));
        assert!(matches!(err, Error::Artifact(_)), "{name}: {err}");
    }
}

/// A poisoned `PackedR` pad lane (only expressible when `r % VL != 0`) is
/// named by `pad-lanes-zero` — the r-kernels MAC pad lanes unconditionally,
/// so a nonzero one silently corrupts results without ever going
/// out of bounds.
#[test]
fn mutant_pad_lane_poison_is_named() {
    use ttrv::compiler::plan::TilePlan;
    use ttrv::compiler::{LoopOrder, OptimizationPlan, VectorLoop};
    // r = 3 pads to one vector of VL = 8 under PackedR — hand-built so the
    // test controls the layout instead of trusting the compiler's pick
    let dims = EinsumDims { kind: EinsumKind::Middle, m: 4, b: 2, n: 2, r: 3, k: 2 };
    let plan = OptimizationPlan {
        dims,
        pack_g: true,
        vector_loop: VectorLoop::R,
        vl: VL,
        rb: RbFactors { rm: 2, rb: 2, rr: 1, rk: 1 },
        tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
        threads: 1,
        ls_estimate: 0,
    };
    let mut rng = Rng::new(17);
    let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, &mut rng);
    let mut pg = pack(&g, &plan).unwrap();
    assert!(check_packed(&plan, &pg).is_empty());
    // poison the lane right past r in the first vector
    let lane = dims.r; // lane_r = 3 >= r
    pg.data[lane] = 0.25;
    let vs = check_packed(&plan, &pg);
    assert_eq!(vs.len(), 1, "{vs:?}");
    assert_eq!(vs[0].invariant, "pad-lanes-zero");
    // same proof on the int8 shadow
    pg.data[lane] = 0.0;
    let mut q = quantize(&pg);
    assert!(check_quant(&plan, &q).is_empty());
    q.data[lane] = 1;
    let vs = check_quant(&plan, &q);
    assert_eq!(vs.len(), 1, "{vs:?}");
    assert_eq!(vs[0].invariant, "pad-lanes-zero");
}

/// The golden artifact decodes through the strict gate and lints clean —
/// the no-false-positives pin for the on-disk format.
#[test]
fn golden_bundle_lints_clean() {
    let bytes = std::fs::read(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/data/lenet300.ttrv"
    ))
    .expect("golden bundle");
    // read_bundle_bytes itself runs the strict gate (chokepoint 2)...
    let bundle = artifact::read_bundle_bytes(&bytes).unwrap();
    // ...and the full lint walk agrees, machine resolved from META
    let report = artifact::lint_bundle(&bundle);
    assert!(report.machine_known, "golden bundle machine {:?}", report.machine);
    assert!(report.plans_checked() > 0);
    assert!(report.clean(), "golden bundle must lint clean");
}

/// Fresh compressions — plain, quantized, and TUNE-shaped — all lint
/// clean, including through a byte round-trip of the gated reader.
#[test]
fn fresh_and_quantized_compressions_lint_clean() {
    let b = base_bundle();
    let report = artifact::lint_bundle(b);
    assert!(report.clean(), "{:?}", report.rows.iter().flat_map(|r| &r.violations).collect::<Vec<_>>());
    // rows cover selected and tuned sources, all with the int8 shadow
    assert!(report.rows.iter().any(|r| r.source == artifact::PlanSource::Selected && r.quant));
    assert!(report.rows.iter().any(|r| r.source == artifact::PlanSource::Tuned));
    let back = artifact::read_bundle_bytes(&artifact::write_bundle(b)).unwrap();
    assert_eq!(&back, b);
}

/// Every zoo model's DSE-selected plan chains pass the strict tier, and
/// cores packed for those plans pass every geometry/pad-lane/quant
/// cross-check — the whole catalog is verifier-clean without a single
/// false positive. (Runs on the plan/pack layer directly so the big
/// ImageNet/GPT shapes don't need a full TT-SVD of demo weights.)
#[test]
fn all_zoo_models_plans_lint_clean() {
    let machine = k1();
    let cfg = DseConfig::default();
    let mut seen = std::collections::HashSet::new();
    let mut rng = Rng::new(23);
    let mut tt_layers = 0usize;
    for model in models::all_models() {
        for shape in model.fc_shapes() {
            if !seen.insert((shape.n, shape.m)) {
                continue;
            }
            let Route::Tt(sel) = router::route_layer(shape.m, shape.n, 8, &machine, &cfg)
                .unwrap_or(Route::Dense)
            else {
                continue;
            };
            tt_layers += 1;
            let layout = sel.layout().clone();
            let cores = random_cores(&layout, &mut rng);
            for (step, dims) in einsum_chain(&layout, 1).iter().enumerate() {
                let plan = compile(dims, &machine).unwrap();
                let vs = check_plan_for(&plan, &machine);
                assert!(vs.is_empty(), "{} [{}x{}] step {step}: {vs:?}", model.name, shape.n, shape.m);
                let pg = pack(&cores.cores[layout.d() - 1 - step], &plan).unwrap();
                let vs = check_packed(&plan, &pg);
                assert!(vs.is_empty(), "{} [{}x{}] step {step}: {vs:?}", model.name, shape.n, shape.m);
                let vs = check_quant(&plan, &quantize(&pg));
                assert!(vs.is_empty(), "{} [{}x{}] step {step}: {vs:?}", model.name, shape.n, shape.m);
            }
        }
    }
    assert!(tt_layers >= 10, "expected a broad TT-routed sample, got {tt_layers}");
}

/// The lint report JSON round-trips the document contract `ttrv lint
/// --json` prints (schema `ttrv-lint-report` v1, checked in CI by
/// `check_bench_json.py`).
#[test]
fn lint_report_json_contract() {
    let mut b = base_bundle().clone();
    tt0(&mut b).plans[0].threads = 0;
    let report = artifact::lint_bundle(&b);
    let doc = report.to_json("mutant:threads-zero");
    assert_eq!(doc.get("schema").and_then(ttrv::util::json::Json::as_str), Some("ttrv-lint-report"));
    assert_eq!(doc.get("clean").and_then(ttrv::util::json::Json::as_bool), Some(false));
    let violations = doc.get("violations").and_then(ttrv::util::json::Json::as_usize).unwrap();
    assert!(violations >= 1);
    let results = doc.get("results").and_then(ttrv::util::json::Json::as_arr).unwrap();
    let violated: Vec<_> = results
        .iter()
        .filter(|r| r.get("status").and_then(ttrv::util::json::Json::as_str) == Some("violated"))
        .collect();
    assert_eq!(violated.len(), 1);
    let vs = violated[0].get("violations").and_then(ttrv::util::json::Json::as_arr).unwrap();
    assert_eq!(
        vs[0].get("invariant").and_then(ttrv::util::json::Json::as_str),
        Some("threads-positive")
    );
}
