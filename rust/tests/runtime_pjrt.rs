//! Integration: the PJRT runtime loads the AOT artifacts and its outputs
//! match the Rust-native implementations — the cross-language correctness
//! proof that L1 (Pallas) / L2 (JAX) / L3 (Rust) compose.
//!
//! Requires `make artifacts`; the tests no-op (with a loud note) otherwise.

use ttrv::runtime::Runtime;
use ttrv::tensor::einsum::{fc_batched_ref, tt_einsum_ref};
use ttrv::tensor::Tensor;
use ttrv::ttd::apply::tt_forward;
use ttrv::ttd::TtLayout;
use ttrv::util::prng::Rng;

fn runtime() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        // the default build ships the stub backend whose `open` always
        // fails; skip loudly instead of panicking even when artifacts exist
        eprintln!("SKIP: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn pallas_einsum_artifact_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("tt_einsum_middle_cb5").unwrap();
    let mut rng = Rng::new(31);
    let g = Tensor::randn(vec![8, 7, 32, 8], 1.0, &mut rng);
    let x = Tensor::randn(vec![9, 7, 8], 1.0, &mut rng);
    let out = exe.run(&[g.clone(), x.clone()]).unwrap();
    let want = tt_einsum_ref(&g, &x).unwrap();
    assert_eq!(out.len(), 1);
    assert!(
        out[0].allclose(&want, 1e-4, 1e-4),
        "PJRT-vs-rust maxdiff {}",
        out[0].max_abs_diff(&want).unwrap()
    );
}

#[test]
fn dense_fc_artifact_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("dense_fc_784x300_b16").unwrap();
    let mut rng = Rng::new(32);
    let x = Tensor::randn(vec![16, 784], 1.0, &mut rng);
    let w = Tensor::randn(vec![300, 784], 0.05, &mut rng);
    let b = Tensor::randn(vec![300], 0.1, &mut rng);
    let out = exe.run(&[x.clone(), w.clone(), b.clone()]).unwrap();
    let want = fc_batched_ref(&w, &x, Some(b.data())).unwrap();
    assert!(out[0].allclose(&want, 1e-3, 1e-3));
}

#[test]
fn tt_fc_artifact_matches_rust_tt_forward() {
    let Some(rt) = runtime() else { return };
    // d = 2 artifact: layout m=[20,15], n=[28,28], ranks [1,8,1]
    let exe = rt.compile("tt_fc_784x300_d2_r8_b16").unwrap();
    let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
    let mut rng = Rng::new(33);
    let cores: Vec<Tensor> = layout
        .core_shapes()
        .into_iter()
        .map(|s| Tensor::randn(s.to_vec(), 0.2, &mut rng))
        .collect();
    let bias = Tensor::randn(vec![300], 0.1, &mut rng);
    let x = Tensor::randn(vec![16, 784], 1.0, &mut rng);
    let mut args = vec![x.clone()];
    args.extend(cores.iter().cloned());
    args.push(bias.clone());
    let out = exe.run(&args).unwrap();
    let want = tt_forward(&cores, &x, Some(bias.data())).unwrap();
    assert!(
        out[0].allclose(&want, 1e-3, 1e-3),
        "maxdiff {}",
        out[0].max_abs_diff(&want).unwrap()
    );
}

#[test]
fn tt_fc_d5_artifact_matches_rust_tt_forward() {
    let Some(rt) = runtime() else { return };
    // the paper's Sec. 2 running example layout at batch 1
    let exe = rt.compile("tt_fc_784x300_d5_r8_b1").unwrap();
    let layout =
        TtLayout::with_uniform_rank(vec![5, 5, 3, 2, 2], vec![2, 2, 2, 7, 14], 8).unwrap();
    let mut rng = Rng::new(34);
    let cores: Vec<Tensor> = layout
        .core_shapes()
        .into_iter()
        .map(|s| Tensor::randn(s.to_vec(), 0.3, &mut rng))
        .collect();
    let bias = Tensor::zeros(vec![300]);
    let x = Tensor::randn(vec![1, 784], 1.0, &mut rng);
    let mut args = vec![x.clone()];
    args.extend(cores.iter().cloned());
    args.push(bias.clone());
    let out = exe.run(&args).unwrap();
    let want = tt_forward(&cores, &x, Some(bias.data())).unwrap();
    assert!(out[0].allclose(&want, 1e-3, 1e-3));
}

#[test]
fn mlp_artifacts_match_rust_model_math() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("mlp_dense_b1").unwrap();
    let mut rng = Rng::new(35);
    let x = Tensor::randn(vec![1, 784], 1.0, &mut rng);
    let w1 = Tensor::randn(vec![300, 784], 0.05, &mut rng);
    let b1 = Tensor::zeros(vec![300]);
    let w2 = Tensor::randn(vec![100, 300], 0.05, &mut rng);
    let b2 = Tensor::zeros(vec![100]);
    let w3 = Tensor::randn(vec![10, 100], 0.05, &mut rng);
    let b3 = Tensor::zeros(vec![10]);
    let out = exe
        .run(&[x.clone(), w1.clone(), b1, w2.clone(), b2, w3.clone(), b3])
        .unwrap();
    // rust-native: fc -> relu -> fc -> relu -> fc
    let mut h = fc_batched_ref(&w1, &x, None).unwrap();
    h.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
    let mut h2 = fc_batched_ref(&w2, &h, None).unwrap();
    h2.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
    let want = fc_batched_ref(&w3, &h2, None).unwrap();
    assert!(
        out[0].allclose(&want, 1e-3, 1e-3),
        "maxdiff {}",
        out[0].max_abs_diff(&want).unwrap()
    );
}

#[test]
fn shape_validation_errors_are_loud() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("dense_fc_784x300_b1").unwrap();
    // wrong arg count
    assert!(exe.run(&[Tensor::zeros(vec![1, 784])]).is_err());
    // wrong shape
    let bad = exe.run(&[
        Tensor::zeros(vec![2, 784]),
        Tensor::zeros(vec![300, 784]),
        Tensor::zeros(vec![300]),
    ]);
    assert!(bad.is_err());
    // unknown artifact
    assert!(rt.compile("nonexistent").is_err());
}
