//! Integration: the unified Executor entry point.
//!
//! * The same Einsum must produce **byte-identical** output across the three
//!   `G` layouts whose kernels accumulate in the same order (Canonical naive,
//!   PackedR r-vectorized, PackedK scalar) and across 1..4 threads, both
//!   loop orders, and bt tiling — threading and tiling repartition work but
//!   never reassociate a single output element's summation.
//! * The k-vectorized kernel reassociates (lane-split + pairwise reduction),
//!   so it is held to numerical closeness instead.
//! * TT-SVD + interleave roundtrip on d=3/d=4 layouts with non-uniform ranks
//!   and non-dividing (prime-mixed) shapes.
//!
//! This binary is a **tier-1 bitwise pin**: every test runs forced-scalar
//! (portable kernel) so its byte-identity assertions hold on any host.
//! Vector kernels (FMA reassociates low-order bits) are covered by the
//! tolerance differential suite in `kernel_reference.rs` instead.

use ttrv::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
use ttrv::kernels::{pack, Executor, VL};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};
use ttrv::ttd::decompose::{random_cores, tt_svd};
use ttrv::ttd::TtLayout;
use ttrv::util::prng::Rng;

/// Pin this process to the portable reference kernel (first statement of
/// every test here — tests run concurrently and the flag is global, but it
/// is only ever raised, never lowered, so there is no race).
fn force_scalar() {
    ttrv::kernels::set_force_scalar(true);
}

#[allow(clippy::too_many_arguments)]
fn plan_with(
    dims: EinsumDims,
    pack_g: bool,
    vloop: VectorLoop,
    rb: RbFactors,
    order: LoopOrder,
    btl: Option<usize>,
    threads: u32,
) -> OptimizationPlan {
    OptimizationPlan {
        dims,
        pack_g,
        vector_loop: vloop,
        vl: if vloop == VectorLoop::None { 1 } else { VL },
        rb,
        tile: TilePlan { order, btl },
        threads,
        ls_estimate: 0,
    }
}

fn run(ex: &mut Executor, plan: OptimizationPlan, g: &Tensor, x: &Tensor) -> Vec<f32> {
    let pg = pack(g, &plan).unwrap();
    ex.set_plan(plan).unwrap();
    ex.execute(&plan.dims, &pg, x).unwrap().into_vec()
}

#[test]
fn byte_identical_across_layouts_threads_orders_and_tiles() {
    force_scalar();
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(90);
    let mut ex = Executor::new(&machine);
    // Miri runs a few hundred times slower than native; one shape and two
    // thread counts still walk every executor code path there (the UB the
    // Miri CI job hunts is per-path, not per-shape).
    let shapes: &[(usize, usize, usize, usize, usize)] = if cfg!(miri) {
        &[(7, 11, 3, 8, 8)]
    } else {
        &[(7, 11, 3, 8, 8), (13, 29, 2, 16, 8), (5, 9, 4, 8, 1), (16, 32, 6, 8, 8)]
    };
    let max_threads: u32 = if cfg!(miri) { 2 } else { 4 };
    for &(m, b, n, r, k) in shapes {
        let kind = if k == 1 { EinsumKind::First } else { EinsumKind::Middle };
        let dims = EinsumDims { kind, m, b, n, r, k };
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);

        // reference: the Canonical (naive) path
        let want = run(&mut ex, OptimizationPlan::naive(dims), &g, &x);

        // PackedK scalar and PackedR r-vectorized, across threading/tiling
        for threads in 1..=max_threads {
            for order in [LoopOrder::Mbrk, LoopOrder::Bmrk] {
                for btl in [None, Some(5)] {
                    let scalar = plan_with(
                        dims, true, VectorLoop::None, RbFactors::NONE, order, btl, threads,
                    );
                    assert_eq!(
                        run(&mut ex, scalar, &g, &x),
                        want,
                        "PackedK scalar differs: {dims:?} T={threads} {order:?} btl={btl:?}"
                    );
                    for (rm, rbf) in [(1usize, 1usize), (2, 3), (4, 2), (8, 8)] {
                        let rbl = RbFactors { rm, rb: rbf, rr: 1, rk: 1 };
                        let rplan =
                            plan_with(dims, true, VectorLoop::R, rbl, order, btl, threads);
                        assert_eq!(
                            run(&mut ex, rplan, &g, &x),
                            want,
                            "PackedR differs: {dims:?} rb=({rm},{rbf}) T={threads} \
                             {order:?} btl={btl:?}"
                        );
                    }
                }
            }
        }

        // k-vectorized reassociates the contraction: close, not bitwise
        let kplan = plan_with(
            dims, true, VectorLoop::K, RbFactors::NONE, LoopOrder::Mbrk, None, 1,
        );
        let got = run(&mut ex, kplan, &g, &x);
        for (a, w) in got.iter().zip(&want) {
            assert!((a - w).abs() < 1e-3 + 1e-3 * w.abs(), "{a} vs {w}");
        }
    }
}

/// The no-drift pin: a *forced-scalar* executor built through the normal
/// `Executor::new` dispatch path must select the portable kernel and
/// produce output byte-identical to the canonical scalar reference — i.e.
/// exactly the bytes this suite pinned before runtime kernel dispatch
/// existed. If dispatch ever leaks a vector kernel past the force flag,
/// or the portable kernel's accumulation order changes, this fails.
#[test]
fn forced_scalar_dispatch_output_is_bitwise_identical_to_reference() {
    force_scalar();
    let machine = MachineSpec::spacemit_k1();
    let mut ex = Executor::new(&machine);
    assert_eq!(
        ex.kernel_name(),
        ttrv::kernels::PORTABLE_KERNEL_NAME,
        "forced-scalar dispatch must select the portable kernel"
    );
    let mut rng = Rng::new(92);
    for (m, b, n, r, k) in [(7usize, 11usize, 3usize, 8usize, 8usize), (9, 5, 2, 16, 8)] {
        let kind = if k == 1 { EinsumKind::First } else { EinsumKind::Middle };
        let dims = EinsumDims { kind, m, b, n, r, k };
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let want = ttrv::kernels::naive_einsum(&g, &x).unwrap().into_vec();
        for (pack_g, vloop, rb) in [
            (false, VectorLoop::None, RbFactors::NONE),
            (true, VectorLoop::None, RbFactors::NONE),
            (true, VectorLoop::R, RbFactors { rm: 2, rb: 3, rr: 1, rk: 1 }),
        ] {
            let plan = plan_with(dims, pack_g, vloop, rb, LoopOrder::Mbrk, None, 1);
            assert_eq!(
                run(&mut ex, plan, &g, &x),
                want,
                "forced-scalar {dims:?} {vloop:?} pack={pack_g} drifted from the reference"
            );
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "pure safe-Rust SVD numerics, no unsafe to check; far too slow under Miri")]
fn ttsvd_roundtrip_d3_d4_nonuniform_ranks_nondividing_shapes() {
    force_scalar();
    let mut rng = Rng::new(91);
    for (ms, ns, truth_ranks, target_ranks) in [
        // d = 3, prime-mixed factors, ranks differ per boundary
        (vec![6u64, 5, 2], vec![4u64, 3, 7], vec![1u64, 4, 2, 1], vec![1u64, 6, 4, 1]),
        (vec![7, 4, 3], vec![3, 5, 2], vec![1, 3, 5, 1], vec![1, 5, 8, 1]),
        // d = 4
        (vec![5, 3, 2, 2], vec![2, 3, 5, 2], vec![1, 2, 4, 2, 1], vec![1, 4, 6, 4, 1]),
    ] {
        let truth_layout = TtLayout::new(ms.clone(), ns.clone(), truth_ranks).unwrap();
        let truth = random_cores(&truth_layout, &mut rng);
        let w = truth.reconstruct().unwrap();
        let target = TtLayout::new(ms.clone(), ns.clone(), target_ranks.clone()).unwrap();
        let tt = tt_svd(&w, &target).unwrap();
        // the truth is exactly representable at the target ranks: exact
        let err = tt.rel_error(&w).unwrap();
        assert!(err < 1e-3, "{} err {err}", target.describe());
        // achieved ranks never exceed the request
        for (a, r) in tt.layout.ranks().iter().zip(&target_ranks) {
            assert!(a <= r, "achieved {a} > requested {r}");
        }
        // cores carry the achieved-layout shapes and the chain forward
        // agrees with the dense reconstruction
        for (t, c) in tt.cores.iter().enumerate() {
            assert_eq!(c.dims(), tt.layout.core_shape(t));
        }
        let n_total = target.n_total() as usize;
        let x = Tensor::randn(vec![3, n_total], 1.0, &mut rng);
        let via_chain = ttrv::ttd::apply::tt_forward(&tt.cores, &x, None).unwrap();
        let w_hat = tt.reconstruct().unwrap();
        let via_dense = ttrv::tensor::einsum::fc_batched_ref(&w_hat, &x, None).unwrap();
        assert!(via_chain.allclose(&via_dense, 1e-3, 1e-3));
    }
}

#[test]
#[cfg_attr(miri, ignore = "pure safe-Rust SVD numerics, no unsafe to check; far too slow under Miri")]
fn property_full_rank_ttsvd_exact_on_random_awkward_shapes() {
    force_scalar();
    ttrv::testkit::check("tt-svd full-rank exactness", 6, |d| {
        let dlen = *d.choose(&[3usize, 4]);
        // keep unfoldings small enough for the Jacobi SVD: primes for d=3,
        // {2,3} for d=4
        let pool: &[u64] = if dlen == 3 { &[2, 3, 5] } else { &[2, 3] };
        let ms: Vec<u64> = (0..dlen).map(|_| *d.choose(pool)).collect();
        let ns: Vec<u64> = (0..dlen).map(|_| *d.choose(pool)).collect();
        let m_total: u64 = ms.iter().product();
        let n_total: u64 = ns.iter().product();
        let mut rng = d.rng().fork();
        let w = Tensor::randn(vec![m_total as usize, n_total as usize], 1.0, &mut rng);
        // unconstrained ranks: achieved ranks clip to the unfolding ranks
        // and the decomposition must be exact
        let target = TtLayout::new(ms, ns, vec![10_000; dlen + 1].into_iter()
            .enumerate()
            .map(|(i, r)| if i == 0 || i == dlen { 1 } else { r })
            .collect())
            .map_err(|e| e.to_string())?;
        let tt = tt_svd(&w, &target).map_err(|e| e.to_string())?;
        let err = tt.rel_error(&w).map_err(|e| e.to_string())?;
        if err < 1e-3 {
            Ok(())
        } else {
            Err(format!("{}: full-rank err {err}", target.describe()))
        }
    });
}
