//! Serving example: co-host the TT-compressed LeNet300 and its equivalent
//! dense model in ONE coordinator process (one registry, one sharded
//! queue, one worker pool), drive both with the same synthetic request
//! trace routed by model id, and compare per-model throughput/latency and
//! memory side by side.
//!
//! Run: `cargo run --release --example serve_compressed [requests] [workers]`
//!
//! `workers` (default 1) sizes the coordinator's batching-worker pool;
//! each worker shares the compiled models and owns a private executor, so
//! responses are identical at any pool size while throughput scales with
//! cores. Batches never mix models, so the TT and dense engines compete
//! for the same workers exactly like two tenants on one edge device. Try
//! `serve_compressed 2000 4` on a multi-core host.

use std::time::Instant;

use ttrv::baselines::dense::DenseFc;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{
    InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine,
};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

fn build_models(rng: &mut Rng) -> ttrv::Result<(ModelEngine, ModelEngine, usize, usize)> {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    let mut tt_ops = Vec::new();
    let mut dense_ops = Vec::new();
    let mut tt_params = 0usize;
    let mut dense_params = 0usize;
    for (i, &(n, m)) in shapes.iter().enumerate() {
        dense_params += (n * m + m) as usize;
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg)? {
            Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), rng);
                tt.bias = Some(vec![0.0; m as usize]);
                tt_params += tt.param_count();
                let w = tt.reconstruct()?;
                println!(
                    "layer {i}: TT {} ({} params, modeled {:.1}x vs dense)",
                    sol.layout().describe(),
                    sol.solution.params,
                    sol.speedup
                );
                tt_ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine)?));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
            }
            Route::Dense => {
                println!("layer {i}: dense [{n} -> {m}]");
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, rng);
                tt_params += (n * m + m) as usize;
                tt_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
            }
        }
        if i + 1 < shapes.len() {
            tt_ops.push(LayerOp::Relu);
            dense_ops.push(LayerOp::Relu);
        }
    }
    Ok((
        ModelEngine::new("lenet300-tt", tt_ops, 784, 10),
        ModelEngine::new("lenet300-dense", dense_ops, 784, 10),
        tt_params,
        dense_params,
    ))
}

fn main() -> ttrv::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let mut rng = Rng::new(7);
    let (tt_model, dense_model, tt_params, dense_params) = build_models(&mut rng)?;
    println!(
        "\nmodel size: dense {dense_params} params vs TT-routed {tt_params} params ({:.1}x)\n",
        dense_params as f64 / tt_params as f64
    );
    let cfg = ServeConfig {
        max_batch: 16,
        max_wait_us: 300,
        queue_cap: 4096,
        workers,
        ..ServeConfig::default()
    };
    cfg.validate()?;
    println!(
        "coordinator: {workers} worker(s), max_batch {}, wait {}us, both models co-hosted\n",
        cfg.max_batch, cfg.max_wait_us
    );

    // one server, two models — requests carry the model id
    let server = Server::start_multi(vec![tt_model, dense_model], cfg)?;
    let names = ["lenet300-tt", "lenet300-dense"];

    // pre-generate the trace so the submission burst is tight and the
    // dynamic batcher actually gets to group requests; each input goes to
    // BOTH models so the comparison sees identical work
    let inputs: Vec<Vec<f32>> = (0..requests).map(|_| rng.normal_vec(784, 1.0)).collect();
    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .flat_map(|(id, input)| {
            names.iter().enumerate().map(move |(mi, name)| {
                InferenceRequest::new((id * 2 + mi) as u64, input.clone()).for_model(*name)
            })
        })
        .map(|req| server.submit(req).expect("admitted"))
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests ({requests} per model) in {:>8.1} ms ({:>7.0} req/s)\n",
        2 * requests,
        wall * 1e3,
        (2 * requests) as f64 / wall
    );
    for name in names {
        let m = server.metrics_for(name)?;
        println!("{name:>15}: {}", m.summary());
    }
    let tt_exec = server.metrics_for(names[0])?.exec.mean_us();
    let dense_exec = server.metrics_for(names[1])?.exec.mean_us();
    if tt_exec > 0.0 {
        println!("\nmean exec ratio dense/TT: {:.2}x", dense_exec / tt_exec);
    }

    // the machine-readable view of everything printed above
    println!("\n{}", ttrv::util::json::to_string_pretty(&server.snapshot()));
    server.shutdown();
    Ok(())
}
