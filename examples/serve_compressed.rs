//! Serving example: stand up the coordinator on a TT-compressed LeNet300
//! and on the equivalent dense model, drive both with the same synthetic
//! request trace, and compare throughput/latency and memory.
//!
//! Run: `cargo run --release --example serve_compressed [requests] [workers]`
//!
//! `workers` (default 1) sizes the coordinator's batching-worker pool;
//! each worker shares the compiled model and owns a private executor, so
//! responses are identical at any pool size while throughput scales with
//! cores. Try `serve_compressed 2000 4` on a multi-core host.

use std::time::Instant;

use ttrv::baselines::dense::DenseFc;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{
    InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine,
};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

fn build_models(rng: &mut Rng) -> ttrv::Result<(ModelEngine, ModelEngine, usize, usize)> {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    let mut tt_ops = Vec::new();
    let mut dense_ops = Vec::new();
    let mut tt_params = 0usize;
    let mut dense_params = 0usize;
    for (i, &(n, m)) in shapes.iter().enumerate() {
        dense_params += (n * m + m) as usize;
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg)? {
            Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), rng);
                tt.bias = Some(vec![0.0; m as usize]);
                tt_params += tt.param_count();
                let w = tt.reconstruct()?;
                println!(
                    "layer {i}: TT {} ({} params, modeled {:.1}x vs dense)",
                    sol.layout().describe(),
                    sol.solution.params,
                    sol.speedup
                );
                tt_ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine)?));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
            }
            Route::Dense => {
                println!("layer {i}: dense [{n} -> {m}]");
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, rng);
                tt_params += (n * m + m) as usize;
                tt_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
                dense_ops.push(LayerOp::Dense(DenseFc::new(&w, None)?));
            }
        }
        if i + 1 < shapes.len() {
            tt_ops.push(LayerOp::Relu);
            dense_ops.push(LayerOp::Relu);
        }
    }
    Ok((
        ModelEngine::new("lenet300-tt", tt_ops, 784, 10),
        ModelEngine::new("lenet300-dense", dense_ops, 784, 10),
        tt_params,
        dense_params,
    ))
}

fn drive(server: &Server, requests: usize, rng: &mut Rng) -> (f64, ttrv::coordinator::metrics::Metrics) {
    // pre-generate the trace so the submission burst is tight and the
    // dynamic batcher actually gets to group requests
    let inputs: Vec<Vec<f32>> = (0..requests).map(|_| rng.normal_vec(784, 1.0)).collect();
    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(id, input)| {
            server
                .submit(InferenceRequest { id: id as u64, input })
                .expect("admitted")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    (t0.elapsed().as_secs_f64(), server.metrics())
}

fn main() -> ttrv::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let mut rng = Rng::new(7);
    let (tt_model, dense_model, tt_params, dense_params) = build_models(&mut rng)?;
    println!(
        "\nmodel size: dense {dense_params} params vs TT-routed {tt_params} params ({:.1}x)\n",
        dense_params as f64 / tt_params as f64
    );
    let cfg = ServeConfig { max_batch: 16, max_wait_us: 300, queue_cap: 4096, workers };
    cfg.validate()?;
    println!(
        "coordinator: {workers} worker(s), max_batch {}, wait {}us\n",
        cfg.max_batch, cfg.max_wait_us
    );

    let tt_server = Server::start(tt_model, cfg.clone());
    let (tt_time, tt_metrics) = drive(&tt_server, requests, &mut rng);
    tt_server.shutdown();

    let dense_server = Server::start(dense_model, cfg);
    let (dense_time, dense_metrics) = drive(&dense_server, requests, &mut rng);
    dense_server.shutdown();

    println!("TT    : {requests} reqs in {:>8.1} ms  ({:>7.0} req/s)", tt_time * 1e3, requests as f64 / tt_time);
    println!("        {}", tt_metrics.summary());
    println!("dense : {requests} reqs in {:>8.1} ms  ({:>7.0} req/s)", dense_time * 1e3, requests as f64 / dense_time);
    println!("        {}", dense_metrics.summary());
    println!("\nthroughput ratio TT/dense: {:.2}x", dense_time / tt_time);
    Ok(())
}
