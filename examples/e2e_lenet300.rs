//! End-to-end driver (DESIGN.md deliverable): the full pipeline on a real
//! small workload, proving all three layers compose.
//!
//!  1. generate a synthetic 10-class MNIST-like dataset;
//!  2. train a dense LeNet300 MLP (784-300-100-10) from scratch in Rust
//!     (SGD + backprop on the crate's own matmul substrate), logging loss;
//!  3. TT-SVD-factorize the two large FC layers into the artifact layouts
//!     (d = 2, rank 8 — the Sec. 6.4 policy family);
//!  4. measure accuracy dense vs TT and latency dense vs the optimized TT
//!     kernel engine (the paper's headline comparison);
//!  5. feed the SAME factorized weights through the AOT JAX/Pallas artifact
//!     (`mlp_tt_b16.hlo.txt`) via PJRT and assert the outputs match the
//!     native Rust engine — the L1/L2/L3 composition proof.
//!
//! Run: `make artifacts && cargo run --release --example e2e_lenet300`
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use ttrv::baselines::dense::DenseFc;
use ttrv::coordinator::{LayerOp, ModelEngine, TtFcEngine};
use ttrv::linalg::matmul;
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::tt_svd;
use ttrv::ttd::{cost, TtLayout};
use ttrv::util::prng::Rng;

// ---------------------------------------------------------------------------
// Synthetic MNIST-like data: 10 class prototypes + noise.
// ---------------------------------------------------------------------------

struct Dataset {
    x: Tensor,      // (n, 784)
    y: Vec<usize>,  // labels
}

fn make_data(n: usize, rng: &mut Rng) -> (Dataset, Dataset) {
    let protos: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(784, 1.0)).collect();
    let mut gen = |count: usize| {
        let mut x = Tensor::zeros(vec![count, 784]);
        let mut y = Vec::with_capacity(count);
        for i in 0..count {
            let label = rng.gen_range(0, 10);
            y.push(label);
            let noise = rng.normal_vec(784, 0.6);
            let row = &mut x.data_mut()[i * 784..(i + 1) * 784];
            for (j, v) in row.iter_mut().enumerate() {
                *v = protos[label][j] + noise[j];
            }
        }
        Dataset { x, y }
    };
    (gen(n), gen(n / 4))
}

// ---------------------------------------------------------------------------
// Dense MLP with backprop (the training substrate).
// ---------------------------------------------------------------------------

struct Mlp {
    w: [Tensor; 3], // (m, n) each
    b: [Vec<f32>; 3],
}

const DIMS: [(usize, usize); 3] = [(300, 784), (100, 300), (10, 100)];

impl Mlp {
    fn new(rng: &mut Rng) -> Self {
        let w = DIMS.map(|(m, n)| {
            Tensor::randn(vec![m, n], (2.0 / (m + n) as f32).sqrt(), rng)
        });
        let b = DIMS.map(|(m, _)| vec![0.0f32; m]);
        Mlp { w, b }
    }

    /// Forward, returning per-layer activations (inputs to each layer).
    fn forward(&self, x: &Tensor) -> (Vec<Tensor>, Tensor) {
        let mut acts = vec![x.clone()];
        let mut cur = x.clone();
        for (i, (w, b)) in self.w.iter().zip(&self.b).enumerate() {
            let mut z = matmul(&cur, &w.transpose(&[1, 0]).unwrap()).unwrap();
            for row in z.data_mut().chunks_mut(b.len()) {
                for (v, &bv) in row.iter_mut().zip(b) {
                    *v += bv;
                }
            }
            if i < 2 {
                for v in z.data_mut() {
                    *v = v.max(0.0);
                }
                acts.push(z.clone());
            }
            cur = z;
        }
        (acts, cur)
    }

    /// One SGD step on a minibatch; returns the CE loss.
    fn step(&mut self, x: &Tensor, y: &[usize], lr: f32) -> f32 {
        let batch = x.dims()[0];
        let (acts, logits) = self.forward(x);
        // softmax + CE
        let mut probs = logits.clone();
        let mut loss = 0.0f32;
        for (i, row) in probs.data_mut().chunks_mut(10).enumerate() {
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
            loss -= (row[y[i]].max(1e-12)).ln();
        }
        loss /= batch as f32;
        // dlogits = (probs - onehot) / batch
        let mut delta = probs;
        for (i, row) in delta.data_mut().chunks_mut(10).enumerate() {
            row[y[i]] -= 1.0;
            for v in row.iter_mut() {
                *v /= batch as f32;
            }
        }
        // backward through the three layers
        for layer in (0..3).rev() {
            let a_in = &acts[layer]; // (batch, n)
            // dW = delta^T @ a_in ; db = col-sums of delta
            let dw = matmul(&delta.transpose(&[1, 0]).unwrap(), a_in).unwrap();
            let m = DIMS[layer].0;
            let mut db = vec![0.0f32; m];
            for row in delta.data().chunks(m) {
                for (s, v) in db.iter_mut().zip(row) {
                    *s += v;
                }
            }
            if layer > 0 {
                // d(a_in) = delta @ W, masked by relu'
                let mut da = matmul(&delta, &self.w[layer]).unwrap();
                for (v, &a) in da.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *v = 0.0;
                    }
                }
                delta = da;
            }
            // SGD update
            for (wv, gv) in self.w[layer].data_mut().iter_mut().zip(dw.data()) {
                *wv -= lr * gv;
            }
            for (bv, gv) in self.b[layer].iter_mut().zip(&db) {
                *bv -= lr * gv;
            }
        }
        loss
    }
}

fn accuracy(logits: &Tensor, y: &[usize]) -> f64 {
    let mut correct = 0;
    for (row, &label) in logits.data().chunks(10).zip(y) {
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label {
            correct += 1;
        }
    }
    correct as f64 / y.len() as f64
}

fn main() -> ttrv::Result<()> {
    let mut rng = Rng::new(2026);
    let machine = MachineSpec::spacemit_k1();

    // ---- 1-2. data + TT-projected training -------------------------------
    // Accuracy preservation under factorization needs training that is aware
    // of the TT constraint (the paper defers accuracy to its refs [3, 33],
    // which fine-tune). We use iterative hard thresholding: every
    // PROJECT_EVERY steps the two large weight matrices are projected onto
    // the rank-8 TT manifold (TT-SVD -> reconstruct), so SGD converges to
    // weights that the final factorization represents exactly.
    let layouts = [
        TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8)?,
        TtLayout::with_uniform_rank(vec![10, 10], vec![20, 15], 8)?,
    ];
    const PROJECT_EVERY: usize = 25;
    let (train, test) = make_data(2048, &mut rng);
    let mut mlp = Mlp::new(&mut rng);
    println!("== TT-projected training of LeNet300 on synthetic MNIST-like data ==");
    let batch = 64;
    let steps = 400;
    let t_train = Instant::now();
    for step in 0..steps {
        let start = (step * batch) % (train.y.len() - batch);
        let xb = Tensor::from_vec(
            vec![batch, 784],
            train.x.data()[start * 784..(start + batch) * 784].to_vec(),
        )?;
        let yb = &train.y[start..start + batch];
        let loss = mlp.step(&xb, yb, 0.08);
        if (step + 1) % PROJECT_EVERY == 0 || step == steps - 1 {
            for (i, layout) in layouts.iter().enumerate() {
                let tt = tt_svd(&mlp.w[i], layout)?;
                mlp.w[i] = tt.reconstruct()?;
            }
        }
        if step % 50 == 0 || step == steps - 1 {
            println!("  step {step:>4}  loss {loss:.4}");
        }
    }
    println!("trained {steps} steps in {:.1} s", t_train.elapsed().as_secs_f64());
    let (_, logits) = mlp.forward(&test.x);
    let dense_acc = accuracy(&logits, &test.y);
    println!("dense (TT-projected) test accuracy: {:.1}%", 100.0 * dense_acc);

    // ---- 3. factorize the two large FC layers (artifact layouts) --------
    // These d=2 rank-8 aligned layouts are exactly what the DSE's Sec. 6.4
    // selection policy returns for these shapes, and what the AOT artifact
    // (python/compile/model.py LENET300_TT_SPEC) is lowered for.
    let mut tt_layers = Vec::new();
    for (i, layout) in layouts.iter().enumerate() {
        let mut tt = tt_svd(&mlp.w[i], layout)?;
        tt.bias = Some(mlp.b[i].clone());
        println!(
            "layer {i}: {} | params {} -> {} ({:.1}x), recon err {:.3}",
            layout.describe(),
            cost::dense_params(layout.m_total(), layout.n_total()),
            tt.param_count(),
            cost::dense_params(layout.m_total(), layout.n_total()) as f64
                / tt.param_count() as f64,
            tt.rel_error(&mlp.w[i])?
        );
        tt_layers.push(tt);
    }

    // ---- 4. accuracy + latency: dense vs optimized TT engine ------------
    let mut tt_model = ModelEngine::new(
        "lenet300-tt",
        vec![
            LayerOp::Tt(TtFcEngine::new(&tt_layers[0], &machine)?),
            LayerOp::Relu,
            LayerOp::Tt(TtFcEngine::new(&tt_layers[1], &machine)?),
            LayerOp::Relu,
            LayerOp::Dense(DenseFc::new(&mlp.w[2], Some(mlp.b[2].clone()))?),
        ],
        784,
        10,
    );
    let tt_logits = tt_model.forward(&test.x)?;
    let tt_acc = accuracy(&tt_logits, &test.y);
    println!(
        "TT test accuracy: {:.1}% (delta {:+.1} pts, rank 8, no fine-tuning)",
        100.0 * tt_acc,
        100.0 * (tt_acc - dense_acc)
    );

    let mut dense_model = ModelEngine::new(
        "lenet300-dense",
        vec![
            LayerOp::Dense(DenseFc::new(&mlp.w[0], Some(mlp.b[0].clone()))?),
            LayerOp::Relu,
            LayerOp::Dense(DenseFc::new(&mlp.w[1], Some(mlp.b[1].clone()))?),
            LayerOp::Relu,
            LayerOp::Dense(DenseFc::new(&mlp.w[2], Some(mlp.b[2].clone()))?),
        ],
        784,
        10,
    );
    for bsz in [1usize, 16] {
        let x = Tensor::from_vec(vec![bsz, 784], test.x.data()[..bsz * 784].to_vec())?;
        let reps = 300;
        let t0 = Instant::now();
        for _ in 0..reps {
            dense_model.forward(&x)?;
        }
        let dense_t = t0.elapsed().as_secs_f64() / reps as f64;
        let t1 = Instant::now();
        for _ in 0..reps {
            tt_model.forward(&x)?;
        }
        let tt_t = t1.elapsed().as_secs_f64() / reps as f64;
        println!(
            "batch {bsz:>2}: dense {:>9.1} us | TT {:>9.1} us | speedup {:.2}x",
            dense_t * 1e6,
            tt_t * 1e6,
            dense_t / tt_t
        );
    }

    // ---- 5. PJRT cross-check against the JAX/Pallas artifact ------------
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        println!("\nartifacts/ missing — run `make artifacts` for the PJRT cross-check");
        return Ok(());
    }
    let rt = ttrv::runtime::Runtime::open(&artifact_dir)?;
    let exe = rt.compile("mlp_tt_b16")?;
    let x16 = Tensor::from_vec(vec![16, 784], test.x.data()[..16 * 784].to_vec())?;
    let mut args = vec![x16.clone()];
    for tt in &tt_layers {
        args.extend(tt.cores.iter().cloned());
        args.push(Tensor::from_vec(
            vec![tt.bias.as_ref().unwrap().len()],
            tt.bias.clone().unwrap(),
        )?);
    }
    args.push(mlp.w[2].clone());
    args.push(Tensor::from_vec(vec![10], mlp.b[2].clone())?);
    let pjrt_out = exe.run(&args)?;
    let native_out = tt_model.forward(&x16)?;
    let diff = pjrt_out[0].max_abs_diff(&native_out)?;
    println!(
        "\nPJRT (JAX+Pallas artifact) vs native Rust engine: max |diff| = {diff:.2e}"
    );
    assert!(
        pjrt_out[0].allclose(&native_out, 1e-3, 1e-3),
        "cross-language mismatch"
    );
    println!("L1 (Pallas) / L2 (JAX) / L3 (Rust) compose: OK");
    Ok(())
}
