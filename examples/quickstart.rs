//! Quickstart: compress one FC layer end to end.
//!
//! 1. DSE-explore the layer (784 -> 300) and pick a solution (Sec. 6.4
//!    policy);
//! 2. TT-SVD a weight matrix into that layout;
//! 3. compile the einsum chain for the SpacemiT-K1 machine model;
//! 4. run the optimized kernel engine and check it against the dense layer;
//! 5. measured autotuning: re-rank RB/thread candidates per chain einsum
//!    on this host ([`ttrv::kernels::Executor::tune_chain`]).
//!
//! Run: `cargo run --release --example quickstart`
//!
//! For the full measured-performance subsystem — the pinned kernel sweep
//! and the serving sweep, written as schema-versioned BENCH_kernels.json /
//! BENCH_serve.json — run `ttrv bench` (or `ttrv bench --quick`); see
//! docs/ARCHITECTURE.md "Measurement & autotuning".

use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::coordinator::TtFcEngine;
use ttrv::dse;
use ttrv::linalg::matmul;
use ttrv::machine::MachineSpec;
use ttrv::tensor::einsum::fc_batched_ref;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost;
use ttrv::ttd::decompose::tt_svd;
use ttrv::util::prng::Rng;

fn main() -> ttrv::Result<()> {
    let (m_dim, n_dim) = (300u64, 784u64);
    let cfg = DseConfig::default();
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(42);

    // 1. explore the design space (all six stages, priced on the K1 model)
    let explored = dse::explore_timed(m_dim, n_dim, &machine, &cfg);
    let counts = &explored.explored.counts;
    println!(
        "DSE for FC [{n_dim} -> {m_dim}]: {} -> {} -> {} -> {} -> {} -> {} solutions",
        ttrv::util::sci(counts.all),
        ttrv::util::sci(counts.aligned),
        counts.vectorized,
        counts.initial,
        counts.scalability,
        explored.timed.len(),
    );
    println!(
        "Pareto frontier over (modeled time, params, FLOPs): {} solutions",
        explored.frontier.len()
    );
    let sol = dse::select_solution(&explored, 8, SelectionPolicy::Balance)?;
    println!(
        "selected: {} ({} params, {} FLOPs, modeled {:.1} us = {:.1}x vs dense)",
        sol.layout().describe(),
        sol.solution.params,
        sol.solution.flops,
        sol.time_s * 1e6,
        sol.speedup,
    );
    let sol = sol.solution;
    println!(
        "dense:    {} params, {} FLOPs  => {:.1}x param / {:.1}x FLOP compression",
        cost::dense_params(m_dim, n_dim),
        cost::dense_flops(m_dim, n_dim),
        cost::dense_params(m_dim, n_dim) as f64 / sol.params as f64,
        cost::dense_flops(m_dim, n_dim) as f64 / sol.flops as f64
    );

    // 2. decompose a (synthetic low-rank-ish) trained weight matrix
    let u = Tensor::randn(vec![m_dim as usize, 24], 0.3, &mut rng);
    let v = Tensor::randn(vec![24, n_dim as usize], 0.3, &mut rng);
    let w = matmul(&u, &v)?;
    let mut tt = tt_svd(&w, &sol.layout)?;
    tt.bias = Some(vec![0.0; m_dim as usize]);
    println!(
        "TT-SVD reconstruction error: {:.4} (achieved ranks {:?})",
        tt.rel_error(&w)?,
        tt.layout.ranks()
    );

    // 3+4. compile + execute the optimized chain, compare to dense
    let mut engine = TtFcEngine::new(&tt, &machine)?;
    let x = Tensor::randn(vec![4, n_dim as usize], 1.0, &mut rng);
    let y_tt = engine.forward(&x)?;
    let y_dense = fc_batched_ref(&w, &x, Some(&vec![0.0; m_dim as usize]))?;
    println!(
        "inference rel-L2 error vs dense: {:.4} (bounded by the decomposition error)",
        y_tt.rel_l2_error(&y_dense)?
    );

    // show the compiler's decisions for each einsum in the chain
    println!("\ncompiler plans (batch 4):");
    for dims in cost::einsum_chain(&tt.layout, 4) {
        let plan = ttrv::compiler::compile(&dims, &machine)?;
        println!(
            "  {:?} m={} b={} n={} r={} k={}: {:?}, rb=({},{},{},{}), {} threads",
            dims.kind, dims.m, dims.b, dims.n, dims.r, dims.k,
            plan.vector_loop, plan.rb.rm, plan.rb.rb, plan.rb.rr, plan.rb.rk,
            plan.threads
        );
    }
    // 5. measured autotuning: the analytic plans above are the compiler's
    // best guess; tune_chain measures the solver's RB/thread candidates on
    // the real packed cores and caches the winners (output bits unchanged)
    let mut ex = ttrv::kernels::Executor::new(&machine);
    let chain = cost::einsum_chain(&tt.layout, 1);
    let packed: Vec<ttrv::kernels::PackedG> = chain
        .iter()
        .enumerate()
        .map(|(step, dims)| ex.pack(&tt.cores[tt.layout.d() - 1 - step], dims))
        .collect::<ttrv::Result<_>>()?;
    let floor = ttrv::util::timer::MeasureFloor::from_env();
    let tuned = ex.tune_chain(&tt.layout, 1, &packed, &floor)?;
    println!("\nmeasured-autotuned plans (batch 1, this host):");
    for (dims, plan) in chain.iter().zip(&tuned) {
        println!(
            "  {:?} m={} b={}: rb=({},{},{},{}), {} threads",
            dims.kind, dims.m, dims.b,
            plan.rb.rm, plan.rb.rb, plan.rb.rr, plan.rb.rk, plan.threads
        );
    }
    println!("(persist these with `ttrv compress --tune`; sweep everything with `ttrv bench`)");
    println!("\nquickstart OK");
    Ok(())
}
