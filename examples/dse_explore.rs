//! DSE exploration across a whole model: the Table-1 workflow as a user
//! would run it — per-layer design-space reduction through all six engine
//! stages, the Pareto frontier over (modeled time, params, FLOPs),
//! alternates for accuracy fallback, and the compiled plan of the winner.
//!
//! Run: `cargo run --release --example dse_explore [model]`
//! (model defaults to AlexNet-CIFAR10; try LeNet300, VGG-CIFAR10, GPT3-Ada)

use ttrv::compiler::compile;
use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::dse;
use ttrv::dse::report::MIN_FC_DIM;
use ttrv::machine::MachineSpec;
use ttrv::models::model_by_name;
use ttrv::ttd::cost;

fn main() -> ttrv::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "AlexNet-CIFAR10".into());
    let model = model_by_name(&name)
        .unwrap_or_else(|| panic!("unknown model '{name}' (see models::all_models)"));
    // four workers: byte-identical output, quicker pricing of big layers
    let cfg = DseConfig { dse_workers: 4, ..Default::default() };
    let machine = MachineSpec::spacemit_k1();
    println!("model: {} ({})", model.name, model.dataset);
    println!(
        "FC share: {:.1}% of params, {:.1}% of FLOPs\n",
        model.fc_param_share(),
        model.fc_flops_share()
    );

    for fc in model.fc_shapes() {
        if fc.n < MIN_FC_DIM || fc.m < MIN_FC_DIM {
            println!(
                "[{} -> {}] x{}: below factorization floor, kept dense\n",
                fc.n, fc.m, fc.count
            );
            continue;
        }
        let e = dse::explore_timed(fc.m, fc.n, &machine, &cfg);
        let c = &e.explored.counts;
        println!(
            "[{} -> {}] x{}: DS {} -> {} -> {} -> {} -> {} -> {} ({} on the frontier)",
            fc.n,
            fc.m,
            fc.count,
            ttrv::util::sci(c.all),
            ttrv::util::sci(c.aligned),
            c.vectorized,
            c.initial,
            c.scalability,
            e.timed.len(),
            e.frontier.len(),
        );
        match dse::select_solution(&e, 8, SelectionPolicy::Balance) {
            Err(err) => println!("  no feasible solution: {err}\n"),
            Ok(sol) => {
                println!(
                    "  selected {} | {:.1}x params, {:.1}x FLOPs, modeled {:.1}x time vs dense",
                    sol.layout().describe(),
                    cost::dense_params(fc.m, fc.n) as f64 / sol.solution.params as f64,
                    cost::dense_flops(fc.m, fc.n) as f64 / sol.solution.flops as f64,
                    sol.speedup,
                );
                if let Ok(fast) = dse::select_solution(&e, 8, SelectionPolicy::MinTime) {
                    println!(
                        "  min-time policy: {} (modeled {:.1} us)",
                        fast.layout().describe(),
                        fast.time_s * 1e6
                    );
                }
                for (i, alt) in dse::select::alternates(&e, 3).iter().enumerate() {
                    println!(
                        "  alternate #{i}: {} (flops {}, modeled {:.1} us)",
                        alt.layout().describe(),
                        alt.solution.flops,
                        alt.time_s * 1e6,
                    );
                }
                for dims in cost::einsum_chain(sol.layout(), cfg.batch) {
                    let plan = compile(&dims, &machine)?;
                    println!(
                        "    {:?}: vec={:?} rb=({},{},{},{}) tile={:?} T={} ls~{}",
                        dims.kind,
                        plan.vector_loop,
                        plan.rb.rm,
                        plan.rb.rb,
                        plan.rb.rr,
                        plan.rb.rk,
                        plan.tile.btl,
                        plan.threads,
                        plan.ls_estimate
                    );
                }
                println!();
            }
        }
    }
    Ok(())
}
