//! DSE exploration across a whole model: the Table-1 workflow as a user
//! would run it — per-layer design-space reduction, the survivor shortlist,
//! alternates for accuracy fallback, and the compiled plan of the winner.
//!
//! Run: `cargo run --release --example dse_explore [model]`
//! (model defaults to AlexNet-CIFAR10; try LeNet300, VGG-CIFAR10, GPT3-Ada)

use ttrv::compiler::compile;
use ttrv::config::DseConfig;
use ttrv::dse;
use ttrv::dse::report::MIN_FC_DIM;
use ttrv::machine::MachineSpec;
use ttrv::models::model_by_name;
use ttrv::ttd::cost;

fn main() -> ttrv::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "AlexNet-CIFAR10".into());
    let model = model_by_name(&name)
        .unwrap_or_else(|| panic!("unknown model '{name}' (see models::all_models)"));
    let cfg = DseConfig::default();
    let machine = MachineSpec::spacemit_k1();
    println!("model: {} ({})", model.name, model.dataset);
    println!(
        "FC share: {:.1}% of params, {:.1}% of FLOPs\n",
        model.fc_param_share(),
        model.fc_flops_share()
    );

    for fc in model.fc_shapes() {
        if fc.n < MIN_FC_DIM || fc.m < MIN_FC_DIM {
            println!("[{} -> {}] x{}: below factorization floor, kept dense\n", fc.n, fc.m, fc.count);
            continue;
        }
        let e = dse::explore(fc.m, fc.n, &cfg);
        println!(
            "[{} -> {}] x{}: DS {} -> {} -> {} -> {} -> {}",
            fc.n,
            fc.m,
            fc.count,
            ttrv::util::sci(e.counts.all),
            ttrv::util::sci(e.counts.aligned),
            e.counts.vectorized,
            e.counts.initial,
            e.counts.scalability
        );
        match dse::select_solution(&e, 8) {
            Err(err) => println!("  no feasible solution: {err}\n"),
            Ok(sol) => {
                println!(
                    "  selected {} | {:.1}x params, {:.1}x FLOPs vs dense",
                    sol.layout.describe(),
                    cost::dense_params(fc.m, fc.n) as f64 / sol.params as f64,
                    cost::dense_flops(fc.m, fc.n) as f64 / sol.flops as f64
                );
                for (i, alt) in dse::select::alternates(&e, 3).iter().enumerate() {
                    println!(
                        "  alternate #{i}: {} (flops {})",
                        alt.layout.describe(),
                        alt.flops
                    );
                }
                for dims in cost::einsum_chain(&sol.layout, cfg.batch) {
                    let plan = compile(&dims, &machine)?;
                    println!(
                        "    {:?}: vec={:?} rb=({},{},{},{}) tile={:?} T={} ls~{}",
                        dims.kind,
                        plan.vector_loop,
                        plan.rb.rm,
                        plan.rb.rb,
                        plan.rb.rr,
                        plan.rb.rk,
                        plan.tile.btl,
                        plan.threads,
                        plan.ls_estimate
                    );
                }
                println!();
            }
        }
    }
    Ok(())
}
