//! Artifact pipeline example: compress LeNet300 once, persist it as a
//! versioned `.ttrv` bundle, then warm-start a serving pool from the file
//! and show that (a) cold-start is now decoupled from design-space size and
//! (b) artifact-served outputs are bitwise-identical to the in-process
//! engine.
//!
//! Run: `cargo run --release --example compress_artifact [requests]`

use std::time::Instant;

use ttrv::artifact;
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{InferenceRequest, Server};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::util::prng::Rng;

fn main() -> ttrv::Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(200);
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();

    // Offline: DSE + TT-SVD + compile + pack, persisted once.
    let spec = artifact::CompressSpec::from_zoo("lenet300", 8, 42)?;
    let t0 = Instant::now();
    let bundle = artifact::compress(&spec, &machine, &cfg)?;
    let compress_time = t0.elapsed();
    let path = std::env::temp_dir().join("ttrv_example_lenet300.ttrv");
    artifact::write_bundle_file(&path, &bundle)?;
    println!(
        "compressed {} in {:.2}s -> {} ({} bytes, {} params, {} of {} layers TT)",
        bundle.name,
        compress_time.as_secs_f64(),
        path.display(),
        std::fs::metadata(&path)?.len(),
        bundle.param_count(),
        bundle.tt_layers(),
        bundle.shapes.len(),
    );

    // Deploy-side: decode + warm-start. No DSE, no SVD, plans pre-seeded.
    let t0 = Instant::now();
    let loaded = artifact::read_bundle_file(&path)?;
    let mut warm_engine = loaded.build_engine(&machine)?;
    println!(
        "warm-start from file: {:.1} ms (vs {:.2}s compressing)",
        t0.elapsed().as_secs_f64() * 1e3,
        compress_time.as_secs_f64()
    );

    // The two construction paths agree bitwise.
    let mut direct_engine = bundle.build_engine(&machine)?;
    let mut rng = Rng::new(7);
    let x = Tensor::randn(vec![8, bundle.in_dim], 1.0, &mut rng);
    let a = warm_engine.forward(&x)?;
    let b = direct_engine.forward(&x)?;
    assert!(a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
    println!("artifact-loaded outputs are bitwise-identical to the in-memory engine");

    // Serve straight from the file.
    let serve_cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
    let server = Server::from_artifact(&path, &machine, serve_cfg)?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|id| {
            server
                .submit(InferenceRequest::new(id as u64, rng.normal_vec(784, 1.0)))
                .expect("admitted")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests from the artifact in {:.1} ms ({:.0} req/s)",
        dt * 1e3,
        requests as f64 / dt
    );
    println!("{}", server.metrics().summary());
    server.shutdown();
    std::fs::remove_file(&path)?;
    Ok(())
}
